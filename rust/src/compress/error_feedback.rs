//! Error feedback (memory) for lossy update compression.
//!
//! With biased compressors (Top-K especially) plain compression discards
//! mass every round and convergence stalls. Error feedback accumulates
//! the discarded residual and re-injects it into the next round's update:
//!
//!   send_t   = C(u_t + e_t)
//!   e_{t+1}  = (u_t + e_t) - send_t
//!
//! (Seide et al. 2014; Karimireddy et al. 2019.)

use anyhow::Result;

use crate::compress::codec::{CompressedPayload, Compressor};
use crate::util::par;

/// Per-worker compression state: the residual memory plus round-persistent
/// scratch (corrected/sent), so the steady-state round allocates nothing.
#[derive(Clone, Debug)]
pub struct ErrorFeedback {
    residual: Vec<f32>,
    enabled: bool,
    corrected: Vec<f32>,
    sent: Vec<f32>,
    /// lossless-stage strip buffer for the what-the-server-sees decode
    stage_scratch: Vec<u8>,
}

impl ErrorFeedback {
    pub fn new(n: usize, enabled: bool) -> ErrorFeedback {
        ErrorFeedback {
            residual: vec![0.0; n],
            enabled,
            corrected: Vec::new(),
            sent: Vec::new(),
            stage_scratch: Vec::new(),
        }
    }

    /// Compress `update` with memory; returns the payload to transmit.
    /// The caller should treat the *decompressed* payload as what the
    /// server will see.
    pub fn compress(
        &mut self,
        update: &[f32],
        compressor: &mut Compressor,
    ) -> Result<CompressedPayload> {
        let mut data = Vec::new();
        self.compress_append(update, compressor, &mut data)?;
        Ok(CompressedPayload {
            scheme: compressor.scheme,
            stage: compressor.lossless,
            n: update.len(),
            data,
        })
    }

    /// [`ErrorFeedback::compress`] writing straight into the transport's
    /// frame buffer (no intermediate payload vector). Returns the bytes
    /// appended.
    pub fn compress_append(
        &mut self,
        update: &[f32],
        compressor: &mut Compressor,
        out: &mut Vec<u8>,
    ) -> Result<usize> {
        if !self.enabled {
            return Ok(compressor.compress_append(update, out));
        }
        assert_eq!(update.len(), self.residual.len(), "EF size mismatch");
        // corrected = update + residual (block-parallel into scratch)
        self.corrected.resize(update.len(), 0.0);
        let items: Vec<((&mut [f32], &[f32]), &[f32])> = self
            .corrected
            .chunks_mut(par::BLOCK)
            .zip(update.chunks(par::BLOCK))
            .zip(self.residual.chunks(par::BLOCK))
            .collect();
        par::run_items_auto(update.len(), items, |((c, u), e)| {
            for ((c, &u), &e) in c.iter_mut().zip(u).zip(e) {
                *c = u + e;
            }
        });

        let start = out.len();
        let nbytes = compressor.compress_append(&self.corrected, out);

        // what the server will see, decoded from the appended bytes
        // (through the lossless stage, exactly as the receiver will)
        self.sent.resize(update.len(), 0.0);
        Compressor::decompress_staged_into(
            compressor.scheme,
            compressor.lossless,
            &out[start..],
            &mut self.stage_scratch,
            &mut self.sent,
        )?;

        // e' = corrected - sent (block-parallel)
        let items: Vec<((&mut [f32], &[f32]), &[f32])> = self
            .residual
            .chunks_mut(par::BLOCK)
            .zip(self.corrected.chunks(par::BLOCK))
            .zip(self.sent.chunks(par::BLOCK))
            .collect();
        par::run_items_auto(update.len(), items, |((e, c), s)| {
            for ((e, &c), &s) in e.iter_mut().zip(c).zip(s) {
                *e = c - s;
            }
        });
        Ok(nbytes)
    }

    /// Snapshot the residual memory for the WAL (exact f32 bit patterns;
    /// the scratch buffers are recomputed every call).
    pub fn wal_encode(&self, w: &mut crate::wal::ByteWriter) {
        w.put_usize(self.residual.len());
        for &x in &self.residual {
            w.put_f32(x);
        }
    }

    /// Restore state written by [`ErrorFeedback::wal_encode`].
    pub fn wal_decode(
        &mut self,
        r: &mut crate::wal::ByteReader,
    ) -> Result<()> {
        let n = r.get_usize()?;
        anyhow::ensure!(
            n == self.residual.len(),
            "WAL error-feedback residual has {n} elems, channel expects {}",
            self.residual.len()
        );
        for x in self.residual.iter_mut() {
            *x = r.get_f32()?;
        }
        Ok(())
    }

    /// Current residual L2 norm (diagnostics).
    pub fn residual_norm(&self) -> f64 {
        self.residual
            .iter()
            .map(|&x| (x as f64) * (x as f64))
            .sum::<f64>()
            .sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::codec::{Compression, Compressor};
    use crate::util::rng::Pcg64;

    #[test]
    fn residual_preserves_total_mass() {
        // with EF, sent + residual == update + old residual exactly
        let mut rng = Pcg64::new(1, 0);
        let update: Vec<f32> =
            (0..256).map(|_| rng.normal() as f32).collect();
        let mut ef = ErrorFeedback::new(256, true);
        let mut c = Compressor::new(Compression::TopK { ratio: 0.05 }, 0);
        let payload = ef.compress(&update, &mut c).unwrap();
        let sent = Compressor::decompress(&payload).unwrap();
        for i in 0..256 {
            let reconstructed = sent[i] + ef.residual[i];
            assert!((reconstructed - update[i]).abs() < 1e-6);
        }
    }

    #[test]
    fn memory_eventually_transmits_small_coords() {
        // a coordinate too small to ever win Top-K still gets through
        // once its accumulated residual grows
        let mut update = vec![0.0f32; 64];
        update[0] = 1.0; // always wins
        update[1] = 0.30; // accumulates
        let mut ef = ErrorFeedback::new(64, true);
        let mut c = Compressor::new(Compression::TopK { ratio: 1.0 / 64.0 }, 0);
        let mut delivered_1 = 0.0f32;
        for _ in 0..8 {
            let p = ef.compress(&update, &mut c).unwrap();
            let sent = Compressor::decompress(&p).unwrap();
            delivered_1 += sent[1];
        }
        // 8 rounds * 0.30 = 2.4 total mass; with EF most must arrive
        assert!(delivered_1 > 1.5, "delivered={delivered_1}");

        // without EF nothing ever arrives on coordinate 1
        let mut ef_off = ErrorFeedback::new(64, false);
        let mut got = 0.0f32;
        for _ in 0..8 {
            let p = ef_off.compress(&update, &mut c).unwrap();
            got += Compressor::decompress(&p).unwrap()[1];
        }
        assert_eq!(got, 0.0);
    }

    #[test]
    fn lossless_stage_leaves_residual_exact() {
        // a lossless stage over a lossy codec must not perturb the
        // residual maths: what the server sees is bit-identical to the
        // unstaged decode, so the memory stays byte-for-byte the same
        use crate::compress::lossless::LosslessStage;
        let mut rng = Pcg64::new(2, 0);
        let update: Vec<f32> = (0..512).map(|_| rng.normal() as f32).collect();
        let mut ef_a = ErrorFeedback::new(512, true);
        let mut ef_b = ErrorFeedback::new(512, true);
        let mut ca = Compressor::new(Compression::TopK { ratio: 0.1 }, 4);
        let mut cb = Compressor::new(Compression::TopK { ratio: 0.1 }, 4)
            .with_lossless(LosslessStage::Auto);
        let pa = ef_a.compress(&update, &mut ca).unwrap();
        let pb = ef_b.compress(&update, &mut cb).unwrap();
        let sa = Compressor::decompress(&pa).unwrap();
        let sb = Compressor::decompress(&pb).unwrap();
        assert_eq!(
            sa.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            sb.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
        );
        assert_eq!(ef_a.residual, ef_b.residual);

        // and an exact codec + exact stage leaves no residual at all
        let mut ef = ErrorFeedback::new(512, true);
        let mut c = Compressor::new(Compression::None, 0)
            .with_lossless(LosslessStage::XorFloat);
        let p = ef.compress(&update, &mut c).unwrap();
        assert_eq!(Compressor::decompress(&p).unwrap(), update);
        assert_eq!(ef.residual_norm(), 0.0);
    }

    #[test]
    fn disabled_is_passthrough() {
        let update = vec![1.0f32, -2.0, 3.0];
        let mut ef = ErrorFeedback::new(3, false);
        let mut c = Compressor::new(Compression::None, 0);
        let p = ef.compress(&update, &mut c).unwrap();
        assert_eq!(Compressor::decompress(&p).unwrap(), update);
        assert_eq!(ef.residual_norm(), 0.0);
    }
}

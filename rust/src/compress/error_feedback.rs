//! Error feedback (memory) for lossy update compression.
//!
//! With biased compressors (Top-K especially) plain compression discards
//! mass every round and convergence stalls. Error feedback accumulates
//! the discarded residual and re-injects it into the next round's update:
//!
//!   send_t   = C(u_t + e_t)
//!   e_{t+1}  = (u_t + e_t) - send_t
//!
//! (Seide et al. 2014; Karimireddy et al. 2019.)

use anyhow::Result;

use crate::compress::codec::{CompressedPayload, Compressor};

/// Per-worker compression state: the residual memory.
#[derive(Clone, Debug)]
pub struct ErrorFeedback {
    residual: Vec<f32>,
    enabled: bool,
}

impl ErrorFeedback {
    pub fn new(n: usize, enabled: bool) -> ErrorFeedback {
        ErrorFeedback { residual: vec![0.0; n], enabled }
    }

    /// Compress `update` with memory; returns the payload to transmit.
    /// The caller should treat the *decompressed* payload as what the
    /// server will see.
    pub fn compress(
        &mut self,
        update: &[f32],
        compressor: &mut Compressor,
    ) -> Result<CompressedPayload> {
        assert_eq!(update.len(), self.residual.len(), "EF size mismatch");
        if !self.enabled {
            return Ok(compressor.compress(update));
        }
        let corrected: Vec<f32> = update
            .iter()
            .zip(&self.residual)
            .map(|(u, e)| u + e)
            .collect();
        let payload = compressor.compress(&corrected);
        let sent = Compressor::decompress(&payload)?;
        for ((e, c), s) in self.residual.iter_mut().zip(&corrected).zip(&sent) {
            *e = c - s;
        }
        Ok(payload)
    }

    /// Current residual L2 norm (diagnostics).
    pub fn residual_norm(&self) -> f64 {
        self.residual
            .iter()
            .map(|&x| (x as f64) * (x as f64))
            .sum::<f64>()
            .sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::codec::{Compression, Compressor};
    use crate::util::rng::Pcg64;

    #[test]
    fn residual_preserves_total_mass() {
        // with EF, sent + residual == update + old residual exactly
        let mut rng = Pcg64::new(1, 0);
        let update: Vec<f32> =
            (0..256).map(|_| rng.normal() as f32).collect();
        let mut ef = ErrorFeedback::new(256, true);
        let mut c = Compressor::new(Compression::TopK { ratio: 0.05 }, 0);
        let payload = ef.compress(&update, &mut c).unwrap();
        let sent = Compressor::decompress(&payload).unwrap();
        for i in 0..256 {
            let reconstructed = sent[i] + ef.residual[i];
            assert!((reconstructed - update[i]).abs() < 1e-6);
        }
    }

    #[test]
    fn memory_eventually_transmits_small_coords() {
        // a coordinate too small to ever win Top-K still gets through
        // once its accumulated residual grows
        let mut update = vec![0.0f32; 64];
        update[0] = 1.0; // always wins
        update[1] = 0.30; // accumulates
        let mut ef = ErrorFeedback::new(64, true);
        let mut c = Compressor::new(Compression::TopK { ratio: 1.0 / 64.0 }, 0);
        let mut delivered_1 = 0.0f32;
        for _ in 0..8 {
            let p = ef.compress(&update, &mut c).unwrap();
            let sent = Compressor::decompress(&p).unwrap();
            delivered_1 += sent[1];
        }
        // 8 rounds * 0.30 = 2.4 total mass; with EF most must arrive
        assert!(delivered_1 > 1.5, "delivered={delivered_1}");

        // without EF nothing ever arrives on coordinate 1
        let mut ef_off = ErrorFeedback::new(64, false);
        let mut got = 0.0f32;
        for _ in 0..8 {
            let p = ef_off.compress(&update, &mut c).unwrap();
            got += Compressor::decompress(&p).unwrap()[1];
        }
        assert_eq!(got, 0.0);
    }

    #[test]
    fn disabled_is_passthrough() {
        let update = vec![1.0f32, -2.0, 3.0];
        let mut ef = ErrorFeedback::new(3, false);
        let mut c = Compressor::new(Compression::None, 0);
        let p = ef.compress(&update, &mut c).unwrap();
        assert_eq!(Compressor::decompress(&p).unwrap(), update);
        assert_eq!(ef.residual_norm(), 0.0);
    }
}

//! Delta + zigzag + LEB128 varint codec over little-endian `u32` words.
//!
//! Words are reinterpreted as `i32`, first-differenced with wrapping
//! arithmetic, zigzag-mapped (`(d << 1) ^ (d >> 31)` folds the sign
//! into the LSB so small negative deltas stay small), and emitted as
//! LEB128 varints — 1 byte for deltas under 64, at most 5 bytes per
//! word (+25%). Wins on integer-ish streams: sorted sparse indices and
//! the WAL's XOR-of-bit-pattern parameter deltas, which are mostly
//! zero. The delta chain restarts at every block boundary so blocks
//! encode and decode independently.

use anyhow::{ensure, Context, Result};

use super::Words;

#[inline]
fn zigzag(d: i32) -> u32 {
    ((d << 1) ^ (d >> 31)) as u32
}

#[inline]
fn unzigzag(z: u32) -> i32 {
    ((z >> 1) as i32) ^ -((z & 1) as i32)
}

/// Encode words `[lo, hi)` of `src` (one block).
pub(crate) fn encode_block<W: Words + ?Sized>(
    src: &W,
    lo: usize,
    hi: usize,
    out: &mut Vec<u8>,
) {
    let mut prev = 0i32; // chain restarts per block (parallel decode)
    for i in lo..hi {
        let w = src.word(i) as i32;
        let mut z = zigzag(w.wrapping_sub(prev));
        prev = w;
        while z >= 0x80 {
            out.push((z as u8) | 0x80);
            z >>= 7;
        }
        out.push(z as u8);
    }
}

/// Decode one block into `dst` (`dst.len()` = 4 × the block's word
/// count), writing words back as little-endian bytes.
pub(crate) fn decode_block(enc: &[u8], dst: &mut [u8]) -> Result<()> {
    let mut off = 0usize;
    let mut prev = 0i32;
    for chunk in dst.chunks_exact_mut(4) {
        let mut z = 0u64;
        let mut shift = 0u32;
        loop {
            let b = *enc.get(off).context("varint block: truncated")?;
            off += 1;
            z |= u64::from(b & 0x7f) << shift;
            if b & 0x80 == 0 {
                break;
            }
            shift += 7;
            ensure!(shift < 35, "varint block: value overflows u32");
        }
        ensure!(
            z <= u64::from(u32::MAX),
            "varint block: value overflows u32"
        );
        prev = prev.wrapping_add(unzigzag(z as u32));
        chunk.copy_from_slice(&(prev as u32).to_le_bytes());
    }
    ensure!(off == enc.len(), "varint block: trailing bytes");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(words: &[u32]) -> usize {
        let mut enc = Vec::new();
        encode_block(words, 0, words.len(), &mut enc);
        let mut dst = vec![0u8; words.len() * 4];
        decode_block(&enc, &mut dst).unwrap();
        let back: Vec<u32> = dst
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        assert_eq!(back, words);
        enc.len()
    }

    #[test]
    fn zigzag_is_a_bijection_on_extremes() {
        for d in [0, 1, -1, 63, -64, i32::MAX, i32::MIN] {
            assert_eq!(unzigzag(zigzag(d)), d);
        }
        // small magnitudes map to small codes (the point of zigzag)
        assert_eq!(zigzag(0), 0);
        assert_eq!(zigzag(-1), 1);
        assert_eq!(zigzag(1), 2);
        assert_eq!(zigzag(-2), 3);
    }

    #[test]
    fn mostly_zero_words_cost_one_byte_each() {
        let mut words = vec![0u32; 1000];
        words[500] = 7;
        let n = roundtrip(&words);
        // zeros are delta 0 = 1 byte; the lone 7 costs 1 byte twice
        // (in and back out of the chain)
        assert_eq!(n, 1000);
    }

    #[test]
    fn sorted_indices_pack_tight() {
        let words: Vec<u32> = (0..10_000u32).map(|i| i * 3).collect();
        // constant delta 3 -> 1 byte per word after the first
        assert_eq!(roundtrip(&words), 10_000);
    }

    #[test]
    fn worst_case_is_five_bytes_per_word() {
        // deltas of ±2^30 zigzag past 2^28, so every one needs the
        // full 5 bytes (note i32::MIN/MAX alternation would NOT be a
        // worst case: it wraps to deltas of ±1)
        let words: Vec<u32> = (0..400)
            .map(|i| if i % 2 == 0 { 0 } else { 0x4000_0000 })
            .collect();
        let n = roundtrip(&words);
        assert!(n <= 400 * 5, "{n}");
        assert!(n > 400 * 4, "{n}");
    }

    #[test]
    fn wrapping_deltas_roundtrip() {
        let words =
            [0u32, u32::MAX, 0, 0x8000_0000, 0x7FFF_FFFF, 1, u32::MAX - 1];
        roundtrip(&words);
    }

    #[test]
    fn corrupt_streams_rejected() {
        let mut enc = Vec::new();
        encode_block(&[5u32, 1000, 3][..], 0, 3, &mut enc);
        let mut dst = vec![0u8; 12];
        // truncated mid-varint
        assert!(decode_block(&enc[..enc.len() - 1], &mut dst).is_err());
        // trailing garbage
        let mut long = enc.clone();
        long.push(0);
        assert!(decode_block(&long, &mut dst).is_err());
        // unterminated varint (all continuation bits)
        assert!(decode_block(&[0xFF; 8], &mut dst[..4]).is_err());
    }
}

//! Gorilla/Chimp-family XOR float codec over 32-bit words.
//!
//! Each word is rotated left by one (the sign bit moves to the LSB, so
//! a sign flip between otherwise-close values costs one trailing bit
//! instead of destroying the leading-zero run), XORed with its
//! predecessor, and the surviving significant bits are bit-packed:
//!
//! ```text
//! '0'                                         XOR == 0 (exact repeat)
//! '10' + sig bits                             reuse the previous window
//! '11' + lead(5) + (sig_len-1)(5) + sig bits  open a new window
//! ```
//!
//! A *window* is (leading-zero count, significant length); reuse fires
//! when the current XOR fits inside it, saving the 10-bit window
//! header. Worst case is 44 bits per word (+37.5%); the `Auto` stage
//! falls back to the raw frame when that loses. Chains restart at every
//! [`crate::util::par::BLOCK`]-word block boundary, so blocks encode
//! and decode independently.

use anyhow::{ensure, Result};

use super::{BitReader, BitWriter, Words};

/// Encode words `[lo, hi)` of `src` (one block; `lo < hi`).
pub(crate) fn encode_block<W: Words + ?Sized>(
    src: &W,
    lo: usize,
    hi: usize,
    out: &mut Vec<u8>,
) {
    debug_assert!(lo < hi, "blocks are never empty");
    let mut bw = BitWriter::new(out);
    let mut prev = src.word(lo).rotate_left(1);
    bw.put(prev, 32);
    // the reuse window; sig == 0 means none opened yet
    let (mut w_lead, mut w_sig) = (0u32, 0u32);
    for i in lo + 1..hi {
        let w = src.word(i).rotate_left(1);
        let x = w ^ prev;
        prev = w;
        if x == 0 {
            bw.put(0, 1);
            continue;
        }
        let lead = x.leading_zeros(); // <= 31 since x != 0
        let trail = x.trailing_zeros();
        if w_sig > 0 && lead >= w_lead && trail >= 32 - w_lead - w_sig {
            bw.put(0b10, 2);
            bw.put(x >> (32 - w_lead - w_sig), w_sig);
        } else {
            let sig = 32 - lead - trail; // 1..=32
            bw.put(0b11, 2);
            bw.put(lead, 5);
            bw.put(sig - 1, 5);
            bw.put(x >> trail, sig);
            w_lead = lead;
            w_sig = sig;
        }
    }
    bw.finish();
}

/// Decode one block into `dst` (`dst.len()` = 4 × the block's word
/// count), writing words back as little-endian bytes.
pub(crate) fn decode_block(enc: &[u8], dst: &mut [u8]) -> Result<()> {
    ensure!(dst.len() >= 4, "xor block: empty");
    let mut br = BitReader::new(enc);
    let mut prev = br.get(32)?;
    dst[0..4].copy_from_slice(&prev.rotate_right(1).to_le_bytes());
    let (mut w_lead, mut w_sig) = (0u32, 0u32);
    for chunk in dst.chunks_exact_mut(4).skip(1) {
        let w = if br.get(1)? == 0 {
            prev
        } else if br.get(1)? == 0 {
            ensure!(w_sig > 0, "xor block: window reuse before any window");
            prev ^ (br.get(w_sig)? << (32 - w_lead - w_sig))
        } else {
            let lead = br.get(5)?;
            let sig = br.get(5)? + 1;
            ensure!(lead + sig <= 32, "xor block: bad window {lead}+{sig}");
            w_lead = lead;
            w_sig = sig;
            prev ^ (br.get(sig)? << (32 - lead - sig))
        };
        chunk.copy_from_slice(&w.rotate_right(1).to_le_bytes());
        prev = w;
    }
    ensure!(br.fully_consumed(), "xor block: trailing bits");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(words: &[u32]) -> usize {
        let mut enc = Vec::new();
        encode_block(words, 0, words.len(), &mut enc);
        let mut dst = vec![0u8; words.len() * 4];
        decode_block(&enc, &mut dst).unwrap();
        let back: Vec<u32> = dst
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        assert_eq!(back, words);
        enc.len()
    }

    #[test]
    fn repeats_cost_one_bit() {
        let n = roundtrip(&[0x3F80_0000; 1001]);
        // 32 bits + 1000 repeat bits = 129 bytes
        assert_eq!(n, 129);
    }

    #[test]
    fn window_reuse_kicks_in_on_stable_exponents() {
        // values sharing exponent + high mantissa: XORs live in a
        // stable low window, so most words pay sig + 2 bits
        let words: Vec<u32> =
            (0..4096u32).map(|i| 0x3F80_0000 | (i % 37)).collect();
        let n = roundtrip(&words);
        // steady state is ~8 bits/word (2 control + 6 sig) once the
        // 6-bit window opens — well under a third of the raw 16 KiB
        assert!(n < 4096 * 10 / 8, "windowed packing too large: {n} bytes");
    }

    #[test]
    fn single_word_block() {
        assert_eq!(roundtrip(&[0xDEAD_BEEF]), 4);
    }

    #[test]
    fn worst_case_is_bounded() {
        // alternating complement patterns defeat every window: cost
        // must stay under the documented 44 bits/word
        let words: Vec<u32> = (0..512u32)
            .map(|i| if i % 2 == 0 { 0x5555_5555 } else { 0xAAAA_AAAA })
            .collect();
        let n = roundtrip(&words);
        assert!(n <= 512 * 44 / 8 + 4, "{n}");
    }

    #[test]
    fn sign_flips_stay_cheap() {
        // ±x alternation: the rotate-left(1) preprocessing turns the
        // sign bit into one trailing LSB, keeping windows tiny
        let words: Vec<u32> = (0..1000)
            .map(|i| {
                if i % 2 == 0 {
                    1.5f32.to_bits()
                } else {
                    (-1.5f32).to_bits()
                }
            })
            .collect();
        let n = roundtrip(&words);
        assert!(n < 1000, "sign alternation blew up: {n} bytes");
    }

    #[test]
    fn truncated_stream_errors() {
        let mut enc = Vec::new();
        encode_block(&[1u32, 2, 3, 4][..], 0, 4, &mut enc);
        let mut dst = vec![0u8; 16];
        assert!(decode_block(&enc[..enc.len() - 1], &mut dst).is_err());
        assert!(decode_block(&enc, &mut dst[..12]).is_err());
    }
}

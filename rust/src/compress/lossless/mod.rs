//! Lossless byte-stage wire compression (ROADMAP item 3).
//!
//! A second, *exact* stage applied to every transport frame after the
//! lossy codec (fp16/int8/sparse) has run: cross-cloud WANs bill per
//! byte, so entropy left in the quantized payload is pure egress
//! dollars at zero accuracy cost. Two codecs:
//!
//! * [`xor_float`] — Chimp/Gorilla-family XOR float coding over
//!   `f32::to_bits`: consecutive words are XORed and the surviving
//!   significant bits are bit-packed behind leading/trailing-zero
//!   window headers. Wins on smooth float streams (dense updates,
//!   model broadcasts).
//! * [`delta_varint`] — delta + zigzag + LEB128 varint over the words
//!   as little-endian `u32`s. Wins on integer-ish streams (sparse
//!   index blocks, the WAL's XOR-of-bit-pattern parameter deltas).
//!
//! Both read the payload as a stream of 32-bit words *in place* through
//! the unaligned [`WordFrame`] wrapper (the arroy `UnalignedVector`
//! idiom — no aligned-`Vec` copy on decode or trial-encode), and both
//! cut the stream into fixed [`par::BLOCK`]-word blocks whose
//! delta/XOR chains restart per block: output bytes are bit-identical
//! at any thread count and blocks decode in parallel.
//!
//! Frame layout (self-framing; follows the transport frame header):
//!
//! ```text
//! [tag u8][raw_len u64]                            tag 0 = raw bytes
//! [n_blocks u32][block_len u32 × n][tail_len u32]  tags 1 (xor) / 2 (varint)
//! [encoded blocks ...][raw tail bytes]
//! ```
//!
//! `raw_len % 4` trailing bytes never form a word and are stored
//! verbatim. [`LosslessStage::Auto`] trial-encodes both codecs and
//! keeps the smallest of {xor, varint, raw} (ties resolve in that
//! order), so a staged frame is never more than the 9-byte raw frame
//! header over the unstaged payload.

use anyhow::{ensure, Context, Result};

use crate::util::par;

pub mod delta_varint;
pub mod xor_float;

/// Which lossless stage a [`crate::compress::Compressor`] applies after
/// its lossy codec. `None` keeps the legacy unframed byte layout —
/// frames are byte-identical to before this stage existed.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum LosslessStage {
    #[default]
    None,
    /// XOR float coding (Gorilla/Chimp family), [`xor_float`]
    XorFloat,
    /// delta + zigzag + LEB128 varint, [`delta_varint`]
    DeltaVarint,
    /// trial-encode both and keep the smallest (raw fallback)
    Auto,
}

impl LosslessStage {
    pub fn name(&self) -> &'static str {
        match self {
            LosslessStage::None => "none",
            LosslessStage::XorFloat => "xor",
            LosslessStage::DeltaVarint => "varint",
            LosslessStage::Auto => "auto",
        }
    }

    pub fn parse(s: &str) -> Option<LosslessStage> {
        match s.to_ascii_lowercase().as_str() {
            "none" => Some(LosslessStage::None),
            "xor" | "xor-float" | "chimp" => Some(LosslessStage::XorFloat),
            "varint" | "delta-varint" => Some(LosslessStage::DeltaVarint),
            "auto" => Some(LosslessStage::Auto),
            _ => None,
        }
    }

    pub fn is_none(&self) -> bool {
        matches!(self, LosslessStage::None)
    }

    /// All stages (CLI help / test enumeration).
    pub const ALL: [LosslessStage; 4] = [
        LosslessStage::None,
        LosslessStage::XorFloat,
        LosslessStage::DeltaVarint,
        LosslessStage::Auto,
    ];
}

/// Frame tags (the first payload byte of a staged frame).
const TAG_RAW: u8 = 0;
const TAG_XOR: u8 = 1;
const TAG_VARINT: u8 = 2;

/// Fixed per-frame overhead of the raw fallback: tag + raw_len.
pub const RAW_FRAME_OVERHEAD: usize = 9;

/// A 32-bit-word source the block codecs read from — implemented by the
/// zero-copy [`WordFrame`] byte view (transport frames) and by plain
/// `[u32]` (WAL bit chains), so both paths share one encoder.
pub trait Words: Sync {
    fn len_words(&self) -> usize;
    fn word(&self, i: usize) -> u32;
    /// Copy the whole-word region verbatim (raw-frame fast path).
    fn copy_words_into(&self, out: &mut Vec<u8>) {
        for i in 0..self.len_words() {
            out.extend_from_slice(&self.word(i).to_le_bytes());
        }
    }
}

/// Unaligned in-place word view of a byte payload (the arroy
/// `UnalignedVector` idiom): `#[repr(transparent)]` over `[u8]`, so a
/// `&[u8]` casts to a `&WordFrame` with no copy and no alignment
/// requirement — the codecs read frames where they sit in the transport
/// buffer.
#[repr(transparent)]
pub struct WordFrame {
    bytes: [u8],
}

impl WordFrame {
    pub fn new(bytes: &[u8]) -> &WordFrame {
        // SAFETY: `WordFrame` is `#[repr(transparent)]` over `[u8]` —
        // identical layout, alignment 1, every bit pattern valid — so
        // the cast only changes the slice's nominal type; the returned
        // reference inherits the input lifetime.
        unsafe { &*(bytes as *const [u8] as *const WordFrame) }
    }

    /// Bytes past the last whole word (`len % 4`), stored verbatim.
    pub fn tail(&self) -> &[u8] {
        &self.bytes[self.len_words() * 4..]
    }
}

impl Words for WordFrame {
    fn len_words(&self) -> usize {
        self.bytes.len() / 4
    }

    #[inline]
    fn word(&self, i: usize) -> u32 {
        let b = &self.bytes[i * 4..i * 4 + 4];
        u32::from_le_bytes([b[0], b[1], b[2], b[3]])
    }

    fn copy_words_into(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.bytes[..self.len_words() * 4]);
    }
}

impl Words for [u32] {
    fn len_words(&self) -> usize {
        self.len()
    }

    #[inline]
    fn word(&self, i: usize) -> u32 {
        self[i]
    }
}

// ---- bit I/O (shared by the codecs) ---------------------------------------

/// MSB-first bit writer over a byte vector (u64 accumulator).
pub(crate) struct BitWriter<'a> {
    out: &'a mut Vec<u8>,
    acc: u64,
    nbits: u32,
}

impl<'a> BitWriter<'a> {
    pub(crate) fn new(out: &'a mut Vec<u8>) -> BitWriter<'a> {
        BitWriter { out, acc: 0, nbits: 0 }
    }

    /// Append the low `n` bits of `bits` (MSB first), `1 <= n <= 32`.
    #[inline]
    pub(crate) fn put(&mut self, bits: u32, n: u32) {
        debug_assert!((1..=32).contains(&n));
        debug_assert!(n == 32 || bits >> n == 0);
        self.acc = (self.acc << n) | u64::from(bits);
        self.nbits += n;
        while self.nbits >= 8 {
            self.nbits -= 8;
            self.out.push((self.acc >> self.nbits) as u8);
        }
    }

    /// Flush the partial last byte, zero-padded on the right.
    pub(crate) fn finish(self) {
        if self.nbits > 0 {
            self.out.push(((self.acc << (8 - self.nbits)) & 0xff) as u8);
        }
    }
}

/// MSB-first bit reader matching [`BitWriter`].
pub(crate) struct BitReader<'a> {
    bytes: &'a [u8],
    pos: usize,
    acc: u64,
    nbits: u32,
}

impl<'a> BitReader<'a> {
    pub(crate) fn new(bytes: &'a [u8]) -> BitReader<'a> {
        BitReader { bytes, pos: 0, acc: 0, nbits: 0 }
    }

    #[inline]
    pub(crate) fn get(&mut self, n: u32) -> Result<u32> {
        debug_assert!((1..=32).contains(&n));
        while self.nbits < n {
            let b = *self
                .bytes
                .get(self.pos)
                .context("lossless frame: bitstream truncated")?;
            self.pos += 1;
            self.acc = (self.acc << 8) | u64::from(b);
            self.nbits += 8;
        }
        self.nbits -= n;
        Ok(((self.acc >> self.nbits) & ((1u64 << n) - 1)) as u32)
    }

    /// Every input byte consumed, with only zero padding left over?
    pub(crate) fn fully_consumed(&self) -> bool {
        self.pos == self.bytes.len()
            && self.acc & ((1u64 << self.nbits) - 1) == 0
    }
}

// ---- frame encode ---------------------------------------------------------

/// Append the staged encoding of `data` to `out`; returns bytes
/// appended. A `None`/unknown stage writes the raw frame (tag 0) — the
/// [`crate::compress::Compressor`] short-circuits `None` to the legacy
/// unframed layout before ever calling this.
pub fn encode_append(
    stage: LosslessStage,
    data: &[u8],
    out: &mut Vec<u8>,
) -> usize {
    let frame = WordFrame::new(data);
    encode_src_append(stage, frame, frame.tail(), out)
}

/// Stage a plain word slice (the WAL parameter-chain path; no tail).
pub fn encode_words_append(
    stage: LosslessStage,
    words: &[u32],
    out: &mut Vec<u8>,
) -> usize {
    encode_src_append(stage, words, &[], out)
}

fn encode_src_append<W: Words + ?Sized>(
    stage: LosslessStage,
    src: &W,
    tail: &[u8],
    out: &mut Vec<u8>,
) -> usize {
    let start = out.len();
    match stage {
        LosslessStage::None => encode_raw(src, tail, out),
        LosslessStage::XorFloat => encode_blocks(TAG_XOR, src, tail, out),
        LosslessStage::DeltaVarint => {
            encode_blocks(TAG_VARINT, src, tail, out)
        }
        LosslessStage::Auto => {
            // trial-encode both, keep the smallest framed image; ties
            // and the raw fallback resolve xor < varint < raw, so the
            // choice is a pure function of the payload bytes
            let raw_framed =
                RAW_FRAME_OVERHEAD + src.len_words() * 4 + tail.len();
            let mut xor = Vec::new();
            encode_blocks(TAG_XOR, src, tail, &mut xor);
            let mut var = Vec::new();
            encode_blocks(TAG_VARINT, src, tail, &mut var);
            if xor.len() <= var.len() && xor.len() <= raw_framed {
                out.extend_from_slice(&xor);
            } else if var.len() <= raw_framed {
                out.extend_from_slice(&var);
            } else {
                encode_raw(src, tail, out);
            }
        }
    }
    out.len() - start
}

fn encode_raw<W: Words + ?Sized>(src: &W, tail: &[u8], out: &mut Vec<u8>) {
    out.push(TAG_RAW);
    put_u64(out, (src.len_words() * 4 + tail.len()) as u64);
    src.copy_words_into(out);
    out.extend_from_slice(tail);
}

fn encode_blocks<W: Words + ?Sized>(
    tag: u8,
    src: &W,
    tail: &[u8],
    out: &mut Vec<u8>,
) {
    let n_words = src.len_words();
    let n_blocks = n_words.div_ceil(par::BLOCK);
    let mut blocks: Vec<Vec<u8>> = vec![Vec::new(); n_blocks];
    let items: Vec<(usize, &mut Vec<u8>)> =
        blocks.iter_mut().enumerate().collect();
    par::run_items_auto(n_words, items, |(b, buf)| {
        let lo = b * par::BLOCK;
        let hi = (lo + par::BLOCK).min(n_words);
        match tag {
            TAG_XOR => xor_float::encode_block(src, lo, hi, buf),
            _ => delta_varint::encode_block(src, lo, hi, buf),
        }
    });
    out.push(tag);
    put_u64(out, (n_words * 4 + tail.len()) as u64);
    put_u32(out, n_blocks as u32);
    for b in &blocks {
        put_u32(out, b.len() as u32);
    }
    put_u32(out, tail.len() as u32);
    for b in &blocks {
        out.extend_from_slice(b);
    }
    out.extend_from_slice(tail);
}

// ---- frame decode ---------------------------------------------------------

/// Decode a staged frame into `out` (cleared and resized to `raw_len`).
/// The encoded blocks are read in place (no intermediate copy); their
/// outputs land at fixed offsets, so the parallel per-block decode is
/// thread-count invariant.
pub fn decode_into(data: &[u8], out: &mut Vec<u8>) -> Result<()> {
    let mut off = 0usize;
    let tag = *data.first().context("lossless frame: empty")?;
    off += 1;
    let raw_len = read_u64(data, &mut off)? as usize;
    if tag == TAG_RAW {
        ensure!(
            data.len() - off == raw_len,
            "lossless frame: raw body {} bytes != declared {raw_len}",
            data.len() - off
        );
        out.clear();
        out.extend_from_slice(&data[off..]);
        return Ok(());
    }
    ensure!(
        tag == TAG_XOR || tag == TAG_VARINT,
        "lossless frame: unknown tag {tag}"
    );
    let n_words = raw_len / 4;
    let want_blocks = n_words.div_ceil(par::BLOCK);
    let n_blocks = read_u32(data, &mut off)? as usize;
    ensure!(
        n_blocks == want_blocks,
        "lossless frame: {n_blocks} blocks for {n_words} words \
         (want {want_blocks})"
    );
    let mut lens = Vec::with_capacity(n_blocks);
    for _ in 0..n_blocks {
        lens.push(read_u32(data, &mut off)? as usize);
    }
    let tail_len = read_u32(data, &mut off)? as usize;
    ensure!(
        tail_len == raw_len % 4,
        "lossless frame: tail {tail_len} bytes != {}",
        raw_len % 4
    );
    let enc_total: usize = lens.iter().sum();
    ensure!(
        data.len() - off == enc_total + tail_len,
        "lossless frame: body {} bytes != blocks {enc_total} + tail \
         {tail_len}",
        data.len() - off
    );

    out.clear();
    out.resize(raw_len, 0);
    let (word_out, tail_out) = out.split_at_mut(n_words * 4);
    let mut results: Vec<Result<()>> = Vec::with_capacity(n_blocks);
    results.resize_with(n_blocks, || Ok(()));
    let mut enc_at = off;
    let mut items: Vec<((&[u8], &mut [u8]), &mut Result<()>)> =
        Vec::with_capacity(n_blocks);
    let mut dst_iter = word_out.chunks_mut(par::BLOCK * 4);
    for (b, res) in results.iter_mut().enumerate() {
        let enc = &data[enc_at..enc_at + lens[b]];
        enc_at += lens[b];
        let dst = dst_iter.next().expect("block count checked above");
        items.push(((enc, dst), res));
    }
    par::run_items_auto(n_words, items, |((enc, dst), res)| {
        *res = match tag {
            TAG_XOR => xor_float::decode_block(enc, dst),
            _ => delta_varint::decode_block(enc, dst),
        };
    });
    for r in results {
        r?;
    }
    tail_out.copy_from_slice(&data[enc_at..]);
    Ok(())
}

/// Decode a staged frame back to words (the WAL parameter-chain path).
pub fn decode_words(data: &[u8], out: &mut Vec<u32>) -> Result<()> {
    let mut bytes = Vec::new();
    decode_into(data, &mut bytes)?;
    ensure!(
        bytes.len() % 4 == 0,
        "lossless frame: {} bytes is not a whole word count",
        bytes.len()
    );
    out.clear();
    out.extend(
        bytes
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]])),
    );
    Ok(())
}

// ---- LE field helpers -----------------------------------------------------

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn read_u32(data: &[u8], off: &mut usize) -> Result<u32> {
    let b = data
        .get(*off..*off + 4)
        .context("lossless frame: header truncated")?;
    *off += 4;
    Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
}

fn read_u64(data: &[u8], off: &mut usize) -> Result<u64> {
    let b = data
        .get(*off..*off + 8)
        .context("lossless frame: header truncated")?;
    *off += 8;
    Ok(u64::from_le_bytes([
        b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7],
    ]))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn roundtrip(stage: LosslessStage, data: &[u8]) -> usize {
        let mut enc = vec![0x5Au8; 3]; // dirty prefix: append-only check
        let n = encode_append(stage, data, &mut enc);
        assert_eq!(enc.len(), 3 + n);
        assert_eq!(&enc[..3], &[0x5A; 3]);
        let mut dec = vec![1u8; 7]; // dirty output: cleared by decode
        decode_into(&enc[3..], &mut dec).unwrap();
        assert_eq!(dec, data, "stage {stage:?} ({} bytes)", data.len());
        n
    }

    fn walk_bytes(n_words: usize, seed: u64) -> Vec<u8> {
        let mut rng = Pcg64::new(seed, 77);
        let mut out = Vec::with_capacity(n_words * 4);
        let mut x = 1.0f32;
        for _ in 0..n_words {
            x += rng.normal_ms(0.0, 0.01) as f32;
            out.extend_from_slice(&x.to_le_bytes());
        }
        out
    }

    #[test]
    fn word_frame_reads_unaligned_in_place() {
        // views at every offset of a misaligned buffer decode the same
        // words — the wrapper must not require 4-byte alignment
        let bytes: Vec<u8> = (0u8..41).collect();
        for shift in 0..4 {
            let f = WordFrame::new(&bytes[shift..]);
            assert_eq!(f.len_words(), (41 - shift) / 4);
            for i in 0..f.len_words() {
                let at = shift + i * 4;
                let want = u32::from_le_bytes([
                    bytes[at],
                    bytes[at + 1],
                    bytes[at + 2],
                    bytes[at + 3],
                ]);
                assert_eq!(f.word(i), want);
            }
            assert_eq!(f.tail().len(), (41 - shift) % 4);
        }
    }

    #[test]
    fn bit_io_roundtrips_mixed_widths() {
        let mut rng = Pcg64::new(9, 9);
        let fields: Vec<(u32, u32)> = (0..500)
            .map(|_| {
                let n = 1 + (rng.next_u64() % 32) as u32;
                let v = (rng.next_u64() as u32)
                    & if n == 32 { u32::MAX } else { (1 << n) - 1 };
                (v, n)
            })
            .collect();
        let mut buf = Vec::new();
        let mut bw = BitWriter::new(&mut buf);
        for &(v, n) in &fields {
            bw.put(v, n);
        }
        bw.finish();
        let mut br = BitReader::new(&buf);
        for &(v, n) in &fields {
            assert_eq!(br.get(n).unwrap(), v);
        }
        assert!(br.fully_consumed());
        assert!(br.get(8).is_err(), "read past the end must fail");
    }

    #[test]
    fn all_stages_roundtrip_all_lengths() {
        // cover: empty, tail-only, single word, word+tail, block
        // boundary -1/0/+1, multi-block
        let b = par::BLOCK * 4;
        for len in
            [0, 1, 3, 4, 5, 17, 4096, b - 4, b, b + 4, 3 * b + 7]
        {
            let full = walk_bytes(len / 4 + 1, 5);
            for stage in LosslessStage::ALL {
                roundtrip(stage, &full[..len]);
            }
        }
    }

    #[test]
    fn adversarial_float_patterns_roundtrip_exactly() {
        let specials = [
            f32::NAN,
            f32::from_bits(0x7FC0_0001), // quiet NaN payload
            f32::from_bits(0xFF80_0001), // signaling-ish NaN
            f32::INFINITY,
            f32::NEG_INFINITY,
            f32::from_bits(1),           // smallest denormal
            f32::from_bits(0x8000_0001), // negative denormal
            f32::MIN_POSITIVE,
            0.0,
            -0.0,
            f32::MAX,
            f32::MIN,
        ];
        let mut cases: Vec<Vec<f32>> = vec![
            specials.to_vec(),
            vec![2.0; 300],                                    // constant
            (0..300).map(|i| if i % 2 == 0 { 1.5 } else { -1.5 }).collect(),
            (0..300).map(|i| i as f32 * 0.1).collect(),        // ramp
        ];
        // random walk sprinkled with specials
        let mut rng = Pcg64::new(3, 3);
        let mut walk: Vec<f32> = Vec::new();
        let mut x = 0.5f32;
        for i in 0..2000 {
            x += rng.normal_ms(0.0, 0.05) as f32;
            walk.push(if i % 97 == 0 {
                specials[(i / 97) % specials.len()]
            } else {
                x
            });
        }
        cases.push(walk);
        for xs in &cases {
            let bytes: Vec<u8> =
                xs.iter().flat_map(|x| x.to_le_bytes()).collect();
            for stage in LosslessStage::ALL {
                let mut enc = Vec::new();
                encode_append(stage, &bytes, &mut enc);
                let mut dec = Vec::new();
                decode_into(&enc, &mut dec).unwrap();
                // to_bits-exact: compare the raw bytes, NaNs included
                assert_eq!(dec, bytes, "stage {stage:?}");
            }
        }
    }

    #[test]
    fn auto_never_beats_neither_and_never_expands_past_raw() {
        let mut rng = Pcg64::new(8, 8);
        let noise: Vec<u8> =
            (0..4096).map(|_| rng.next_u64() as u8).collect();
        let smooth = walk_bytes(1024, 2);
        for data in [&noise, &smooth, &Vec::new()] {
            let mut xor = Vec::new();
            encode_append(LosslessStage::XorFloat, data, &mut xor);
            let mut var = Vec::new();
            encode_append(LosslessStage::DeltaVarint, data, &mut var);
            let mut auto = Vec::new();
            encode_append(LosslessStage::Auto, data, &mut auto);
            let best = xor
                .len()
                .min(var.len())
                .min(RAW_FRAME_OVERHEAD + data.len());
            assert_eq!(auto.len(), best);
            assert!(auto.len() <= RAW_FRAME_OVERHEAD + data.len());
        }
    }

    #[test]
    fn auto_picks_raw_on_incompressible_noise() {
        let mut rng = Pcg64::new(4, 4);
        let noise: Vec<u8> =
            (0..8192).map(|_| rng.next_u64() as u8).collect();
        let mut enc = Vec::new();
        encode_append(LosslessStage::Auto, &noise, &mut enc);
        assert_eq!(enc[0], TAG_RAW);
        assert_eq!(enc.len(), RAW_FRAME_OVERHEAD + noise.len());
    }

    #[test]
    fn constant_floats_compress_massively() {
        let data: Vec<u8> =
            std::iter::repeat(2.0f32.to_le_bytes()).take(4096).flatten().collect();
        let mut enc = Vec::new();
        encode_append(LosslessStage::XorFloat, &data, &mut enc);
        // first word 32 bits + 1 bit per repeat ≈ 4+512 bytes + header
        assert!(
            enc.len() < data.len() / 20,
            "{} vs {}",
            enc.len(),
            data.len()
        );
        let mut dec = Vec::new();
        decode_into(&enc, &mut dec).unwrap();
        assert_eq!(dec, data);
    }

    #[test]
    fn word_path_matches_byte_path() {
        // the WAL's &[u32] source must produce the identical frame to
        // the byte view of the same words
        let words: Vec<u32> = (0..5000u32).map(|i| i.wrapping_mul(40_503)).collect();
        let bytes: Vec<u8> =
            words.iter().flat_map(|w| w.to_le_bytes()).collect();
        for stage in LosslessStage::ALL {
            let mut from_words = Vec::new();
            encode_words_append(stage, &words, &mut from_words);
            let mut from_bytes = Vec::new();
            encode_append(stage, &bytes, &mut from_bytes);
            assert_eq!(from_words, from_bytes, "stage {stage:?}");
            let mut back = Vec::new();
            decode_words(&from_words, &mut back).unwrap();
            assert_eq!(back, words, "stage {stage:?}");
        }
    }

    #[test]
    fn corrupt_frames_rejected_not_panicking() {
        let data = walk_bytes(600, 6);
        for stage in [LosslessStage::XorFloat, LosslessStage::DeltaVarint] {
            let mut enc = Vec::new();
            encode_append(stage, &data, &mut enc);
            let mut out = Vec::new();
            // truncations at every layer of the frame
            for cut in [0, 1, 5, 9, 13, enc.len() - 1] {
                assert!(
                    decode_into(&enc[..cut], &mut out).is_err(),
                    "stage {stage:?} cut {cut}"
                );
            }
            // unknown tag
            let mut bad = enc.clone();
            bad[0] = 9;
            assert!(decode_into(&bad, &mut out).is_err());
            // declared length lies
            let mut bad = enc.clone();
            bad[1] ^= 0xFF;
            assert!(decode_into(&bad, &mut out).is_err());
        }
        assert!(decode_into(&[], &mut Vec::new()).is_err());
    }

    #[test]
    fn stage_parse_roundtrips_names() {
        for stage in LosslessStage::ALL {
            assert_eq!(LosslessStage::parse(stage.name()), Some(stage));
        }
        assert_eq!(LosslessStage::parse("chimp"), Some(LosslessStage::XorFloat));
        assert_eq!(
            LosslessStage::parse("delta-varint"),
            Some(LosslessStage::DeltaVarint)
        );
        assert_eq!(LosslessStage::parse("lz4"), None);
        assert!(LosslessStage::None.is_none());
        assert!(!LosslessStage::Auto.is_none());
        assert_eq!(LosslessStage::default(), LosslessStage::None);
    }
}

//! Compression codecs over flat f32 update vectors.

use anyhow::{bail, Result};

use crate::util::bytes::{f32s_to_le, le_to_f32s, le_to_u32s, u32s_to_le};
use crate::util::rng::Pcg64;

/// Compression scheme selector (paper §3.2).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Compression {
    /// dense f32 — the FedAvg baseline
    None,
    /// keep the k largest-magnitude coordinates (sparsification)
    TopK { ratio: f64 },
    /// keep k *random* coordinates (cheaper, unbiased when rescaled)
    RandK { ratio: f64 },
    /// per-chunk affine int8 quantization with stochastic rounding
    Int8,
    /// f32 -> f16 truncation (2x)
    Fp16,
}

impl Compression {
    pub fn name(&self) -> &'static str {
        match self {
            Compression::None => "none",
            Compression::TopK { .. } => "topk",
            Compression::RandK { .. } => "randk",
            Compression::Int8 => "int8",
            Compression::Fp16 => "fp16",
        }
    }

    pub fn parse(s: &str) -> Option<Compression> {
        let s = s.to_ascii_lowercase();
        if s == "none" {
            Some(Compression::None)
        } else if s == "int8" {
            Some(Compression::Int8)
        } else if s == "fp16" {
            Some(Compression::Fp16)
        } else if let Some(r) = s.strip_prefix("topk:") {
            r.parse().ok().map(|ratio| Compression::TopK { ratio })
        } else if let Some(r) = s.strip_prefix("randk:") {
            r.parse().ok().map(|ratio| Compression::RandK { ratio })
        } else {
            None
        }
    }
}

/// A compressed update: opaque bytes + the codec needed to reopen them.
#[derive(Clone, Debug)]
pub struct CompressedPayload {
    pub scheme: Compression,
    pub n: usize,
    pub data: Vec<u8>,
}

impl CompressedPayload {
    pub fn byte_len(&self) -> u64 {
        // + small header: scheme tag (1) + element count (8)
        self.data.len() as u64 + 9
    }
}

/// Stateful compressor (owns the RNG for stochastic schemes).
#[derive(Clone, Debug)]
pub struct Compressor {
    pub scheme: Compression,
    rng: Pcg64,
}

const INT8_CHUNK: usize = 4096;

impl Compressor {
    pub fn new(scheme: Compression, seed: u64) -> Compressor {
        Compressor { scheme, rng: Pcg64::new(seed, 0xC0DEC) }
    }

    /// Compress a flat vector. Exactly reversible layout via `decompress`.
    pub fn compress(&mut self, xs: &[f32]) -> CompressedPayload {
        let data = match self.scheme {
            Compression::None => f32s_to_le(xs),
            Compression::Fp16 => {
                // perf: preallocated tight loop (see EXPERIMENTS.md §Perf);
                // the flat_map form costs ~40% more on this path
                let mut out = Vec::with_capacity(xs.len() * 2);
                for &x in xs {
                    out.extend_from_slice(&f32_to_f16_bits(x).to_le_bytes());
                }
                out
            }
            Compression::Int8 => int8_encode(xs, &mut self.rng),
            Compression::TopK { ratio } => {
                let k = k_of(xs.len(), ratio);
                let idx = top_k_indices(xs, k);
                sparse_encode(xs, &idx, 1.0)
            }
            Compression::RandK { ratio } => {
                let k = k_of(xs.len(), ratio);
                let idx = self.rng.sample_indices(xs.len(), k);
                // unbiased: scale kept coords by n/k
                let scale = xs.len() as f32 / k.max(1) as f32;
                sparse_encode(xs, &idx, scale)
            }
        };
        CompressedPayload { scheme: self.scheme, n: xs.len(), data }
    }

    /// Decompress back to a dense vector of length `payload.n`.
    pub fn decompress(payload: &CompressedPayload) -> Result<Vec<f32>> {
        let n = payload.n;
        match payload.scheme {
            Compression::None => {
                let xs = le_to_f32s(&payload.data)
                    .ok_or_else(|| anyhow::anyhow!("ragged f32 payload"))?;
                if xs.len() != n {
                    bail!("dense payload length {} != {}", xs.len(), n);
                }
                Ok(xs)
            }
            Compression::Fp16 => {
                if payload.data.len() != n * 2 {
                    bail!("fp16 payload length mismatch");
                }
                Ok(payload
                    .data
                    .chunks_exact(2)
                    .map(|c| f16_bits_to_f32(u16::from_le_bytes([c[0], c[1]])))
                    .collect())
            }
            Compression::Int8 => int8_decode(&payload.data, n),
            Compression::TopK { .. } | Compression::RandK { .. } => {
                sparse_decode(&payload.data, n)
            }
        }
    }

    /// Compression ratio estimate (payload bytes / dense bytes).
    pub fn ratio_estimate(&self, n: usize) -> f64 {
        if n == 0 {
            return 1.0;
        }
        let dense = (n * 4) as f64;
        match self.scheme {
            Compression::None => 1.0,
            Compression::Fp16 => 0.5,
            Compression::Int8 => (n as f64 + (n.div_ceil(INT8_CHUNK) * 8) as f64) / dense,
            Compression::TopK { ratio } | Compression::RandK { ratio } => {
                (k_of(n, ratio) * 8) as f64 / dense
            }
        }
    }
}

fn k_of(n: usize, ratio: f64) -> usize {
    ((n as f64 * ratio).round() as usize).clamp(1, n)
}

/// Indices of the k largest |x| (O(n) select via partial sort of a copy).
fn top_k_indices(xs: &[f32], k: usize) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..xs.len()).collect();
    if k < xs.len() {
        idx.select_nth_unstable_by(k - 1, |&a, &b| {
            xs[b].abs().partial_cmp(&xs[a].abs()).unwrap()
        });
        idx.truncate(k);
    }
    idx
}

/// layout: [k u32 count][k u32 indices][k f32 values]
fn sparse_encode(xs: &[f32], idx: &[usize], scale: f32) -> Vec<u8> {
    let mut out = Vec::with_capacity(4 + idx.len() * 8);
    out.extend_from_slice(&(idx.len() as u32).to_le_bytes());
    out.extend_from_slice(&u32s_to_le(
        &idx.iter().map(|&i| i as u32).collect::<Vec<_>>(),
    ));
    out.extend_from_slice(&f32s_to_le(
        &idx.iter().map(|&i| xs[i] * scale).collect::<Vec<_>>(),
    ));
    out
}

fn sparse_decode(data: &[u8], n: usize) -> Result<Vec<f32>> {
    if data.len() < 4 {
        bail!("sparse payload too short");
    }
    let k = u32::from_le_bytes([data[0], data[1], data[2], data[3]]) as usize;
    let want = 4 + k * 8;
    if data.len() != want {
        bail!("sparse payload length {} != {}", data.len(), want);
    }
    let idx = le_to_u32s(&data[4..4 + 4 * k]).unwrap();
    let vals = le_to_f32s(&data[4 + 4 * k..]).unwrap();
    let mut out = vec![0.0f32; n];
    for (&i, &v) in idx.iter().zip(&vals) {
        let i = i as usize;
        if i >= n {
            bail!("sparse index {i} out of range {n}");
        }
        out[i] = v;
    }
    Ok(out)
}

/// int8: per-chunk [min f32][scale f32][n_chunk u8 codes] with stochastic
/// rounding so quantization is unbiased in expectation.
fn int8_encode(xs: &[f32], rng: &mut Pcg64) -> Vec<u8> {
    let mut out = Vec::with_capacity(xs.len() + xs.len().div_ceil(INT8_CHUNK) * 8);
    for chunk in xs.chunks(INT8_CHUNK) {
        let lo = chunk.iter().cloned().fold(f32::INFINITY, f32::min);
        let hi = chunk.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let scale = if hi > lo { (hi - lo) / 255.0 } else { 0.0 };
        out.extend_from_slice(&lo.to_le_bytes());
        out.extend_from_slice(&scale.to_le_bytes());
        if scale == 0.0 {
            out.resize(out.len() + chunk.len(), 0);
            continue;
        }
        // perf (EXPERIMENTS.md §Perf): hoist 1/scale, draw two random
        // lanes per PRNG step, keep the loop branch-light
        let inv_scale = 1.0 / scale;
        let mut i = 0;
        while i < chunk.len() {
            let r = rng.next_u64();
            let r0 = ((r >> 40) as u32) as f32 * (1.0 / (1u32 << 24) as f32);
            let r1 = (((r >> 8) & 0xff_ffff) as u32) as f32
                * (1.0 / (1u32 << 24) as f32);
            for (x, rnd) in chunk[i..chunk.len().min(i + 2)]
                .iter()
                .zip([r0, r1])
            {
                let exact = (x - lo) * inv_scale;
                let base = exact.floor();
                let code = base + f32::from(rnd < exact - base);
                out.push(code.clamp(0.0, 255.0) as u8);
            }
            i += 2;
        }
    }
    out
}

fn int8_decode(data: &[u8], n: usize) -> Result<Vec<f32>> {
    let mut out = Vec::with_capacity(n);
    let mut pos = 0;
    let mut left = n;
    while left > 0 {
        if data.len() < pos + 8 {
            bail!("int8 payload truncated");
        }
        let lo = f32::from_le_bytes(data[pos..pos + 4].try_into().unwrap());
        let scale =
            f32::from_le_bytes(data[pos + 4..pos + 8].try_into().unwrap());
        pos += 8;
        let m = left.min(INT8_CHUNK);
        if data.len() < pos + m {
            bail!("int8 payload truncated");
        }
        for &b in &data[pos..pos + m] {
            out.push(lo + scale * b as f32);
        }
        pos += m;
        left -= m;
    }
    if pos != data.len() {
        bail!("int8 payload has {} trailing bytes", data.len() - pos);
    }
    Ok(out)
}

// ---- f16 conversion (no `half` crate offline) -----------------------------

pub(crate) fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let mut exp = ((bits >> 23) & 0xff) as i32;
    let mut frac = bits & 0x007f_ffff;
    if exp == 0xff {
        // inf / nan
        return sign | 0x7c00 | if frac != 0 { 0x200 } else { 0 };
    }
    exp = exp - 127 + 15;
    if exp >= 0x1f {
        return sign | 0x7c00; // overflow -> inf
    }
    if exp <= 0 {
        // subnormal or zero
        if exp < -10 {
            return sign;
        }
        frac |= 0x0080_0000;
        let shift = 14 - exp;
        let half = frac >> shift;
        // round to nearest even
        let rem = frac & ((1 << shift) - 1);
        let halfway = 1u32 << (shift - 1);
        let rounded = match rem.cmp(&halfway) {
            std::cmp::Ordering::Greater => half + 1,
            std::cmp::Ordering::Equal => half + (half & 1),
            std::cmp::Ordering::Less => half,
        };
        return sign | rounded as u16;
    }
    // normal: round mantissa 23 -> 10 bits, nearest even
    let half = frac >> 13;
    let rem = frac & 0x1fff;
    let mut out = ((exp as u32) << 10) | half;
    match rem.cmp(&0x1000) {
        std::cmp::Ordering::Greater => out += 1,
        std::cmp::Ordering::Equal => out += out & 1,
        std::cmp::Ordering::Less => {}
    }
    sign | out as u16
}

pub(crate) fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1f) as u32;
    let frac = (h & 0x3ff) as u32;
    let bits = if exp == 0 {
        if frac == 0 {
            sign
        } else {
            // subnormal: normalize
            let mut e = -1i32;
            let mut f = frac;
            while f & 0x400 == 0 {
                f <<= 1;
                e -= 1;
            }
            f &= 0x3ff;
            sign | (((127 - 15 + e + 1) as u32) << 23) | (f << 13)
        }
    } else if exp == 0x1f {
        sign | 0x7f80_0000 | (frac << 13)
    } else {
        sign | ((exp + 127 - 15) << 23) | (frac << 13)
    };
    f32::from_bits(bits)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Pcg64::new(seed, 1);
        (0..n).map(|_| rng.normal_ms(0.0, 0.1) as f32).collect()
    }

    #[test]
    fn none_roundtrips_exactly() {
        let xs = sample(1000, 1);
        let mut c = Compressor::new(Compression::None, 0);
        let p = c.compress(&xs);
        assert_eq!(Compressor::decompress(&p).unwrap(), xs);
        assert_eq!(p.byte_len(), 4009);
    }

    #[test]
    fn fp16_halves_and_approximates() {
        let xs = sample(1000, 2);
        let mut c = Compressor::new(Compression::Fp16, 0);
        let p = c.compress(&xs);
        assert_eq!(p.data.len(), 2000);
        let ys = Compressor::decompress(&p).unwrap();
        for (x, y) in xs.iter().zip(&ys) {
            assert!((x - y).abs() < 2e-3 * x.abs().max(0.1), "{x} vs {y}");
        }
    }

    #[test]
    fn f16_special_values() {
        for x in [0.0f32, -0.0, 1.0, -1.0, 65504.0, 1e-7, f32::INFINITY] {
            let y = f16_bits_to_f32(f32_to_f16_bits(x));
            if x.is_finite() && x.abs() > 1e-4 {
                assert!((x - y).abs() / x.abs().max(1e-3) < 1e-3, "{x} -> {y}");
            }
        }
        assert!(f16_bits_to_f32(f32_to_f16_bits(f32::NAN)).is_nan());
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(1e9)), f32::INFINITY);
    }

    #[test]
    fn topk_keeps_largest() {
        let xs = vec![0.1, -5.0, 0.2, 3.0, -0.05, 0.0, 1.0, -0.3];
        let mut c = Compressor::new(Compression::TopK { ratio: 0.25 }, 0);
        let p = c.compress(&xs);
        let ys = Compressor::decompress(&p).unwrap();
        assert_eq!(ys[1], -5.0);
        assert_eq!(ys[3], 3.0);
        assert_eq!(ys.iter().filter(|&&y| y != 0.0).count(), 2);
    }

    #[test]
    fn topk_payload_smaller() {
        let xs = sample(10_000, 3);
        let mut c = Compressor::new(Compression::TopK { ratio: 0.01 }, 0);
        let p = c.compress(&xs);
        assert!(p.byte_len() < 2000, "{}", p.byte_len());
        assert!((c.ratio_estimate(10_000) - 0.02).abs() < 0.01);
    }

    #[test]
    fn randk_unbiased_in_expectation() {
        let xs = vec![1.0f32; 512];
        let mut c = Compressor::new(Compression::RandK { ratio: 0.25 }, 7);
        let mut acc = vec![0.0f64; 512];
        let trials = 400;
        for _ in 0..trials {
            let ys = Compressor::decompress(&c.compress(&xs)).unwrap();
            for (a, y) in acc.iter_mut().zip(&ys) {
                *a += *y as f64;
            }
        }
        let mean: f64 = acc.iter().sum::<f64>() / (512.0 * trials as f64);
        assert!((mean - 1.0).abs() < 0.05, "mean={mean}");
    }

    #[test]
    fn int8_bounded_error_and_unbiased() {
        let xs = sample(8192, 4);
        let mut c = Compressor::new(Compression::Int8, 5);
        let p = c.compress(&xs);
        // ~1 byte/elem + 8B header per 4096 chunk
        assert!(p.data.len() <= 8192 + 2 * 8);
        let ys = Compressor::decompress(&p).unwrap();
        let span = {
            let lo = xs.iter().cloned().fold(f32::INFINITY, f32::min);
            let hi = xs.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            hi - lo
        };
        let step = span / 255.0;
        let mut bias = 0.0f64;
        for (x, y) in xs.iter().zip(&ys) {
            assert!((x - y).abs() <= step * 1.001, "{x} vs {y}");
            bias += (*y - *x) as f64;
        }
        assert!(bias.abs() / 8192.0 < step as f64 * 0.1, "bias={bias}");
    }

    #[test]
    fn int8_constant_chunk() {
        let xs = vec![3.5f32; 100];
        let mut c = Compressor::new(Compression::Int8, 6);
        let ys = Compressor::decompress(&c.compress(&xs)).unwrap();
        assert_eq!(ys, xs);
    }

    #[test]
    fn parse_schemes() {
        assert_eq!(Compression::parse("none"), Some(Compression::None));
        assert_eq!(
            Compression::parse("topk:0.01"),
            Some(Compression::TopK { ratio: 0.01 })
        );
        assert_eq!(
            Compression::parse("randk:0.1"),
            Some(Compression::RandK { ratio: 0.1 })
        );
        assert_eq!(Compression::parse("int8"), Some(Compression::Int8));
        assert_eq!(Compression::parse("zstd"), None);
    }

    #[test]
    fn corrupt_payloads_rejected() {
        let xs = sample(100, 8);
        let mut c = Compressor::new(Compression::TopK { ratio: 0.1 }, 0);
        let mut p = c.compress(&xs);
        p.data.truncate(p.data.len() - 1);
        assert!(Compressor::decompress(&p).is_err());

        let mut c2 = Compressor::new(Compression::Int8, 0);
        let mut p2 = c2.compress(&xs);
        p2.data.push(0);
        assert!(Compressor::decompress(&p2).is_err());
    }

    #[test]
    fn sparse_rejects_out_of_range_index() {
        let data = {
            let mut d = Vec::new();
            d.extend_from_slice(&1u32.to_le_bytes());
            d.extend_from_slice(&999u32.to_le_bytes());
            d.extend_from_slice(&1.0f32.to_le_bytes());
            d
        };
        let p = CompressedPayload {
            scheme: Compression::TopK { ratio: 0.1 },
            n: 10,
            data,
        };
        assert!(Compressor::decompress(&p).is_err());
    }
}

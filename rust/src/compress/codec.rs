//! Compression codecs over flat f32 update vectors.
//!
//! All per-chunk-independent codecs (fp16, int8, sparse gather) are
//! block-parallel via [`par`], and the encode path writes straight into a
//! caller-owned buffer ([`Compressor::compress_append`]) with
//! [`Compressor`]-owned scratch — the steady-state round allocates
//! nothing. Serial and parallel encodes are bit-identical
//! (EXPERIMENTS.md §Perf): block boundaries are fixed and the int8
//! stochastic-rounding stream is seeded per chunk from the compressor RNG
//! *before* fan-out.

use anyhow::{bail, Result};

use crate::compress::lossless::{self, LosslessStage};
use crate::util::bytes::{f32s_to_le_into, le_to_f32s_into};
use crate::util::par;
use crate::util::rng::Pcg64;

/// Compression scheme selector (paper §3.2).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Compression {
    /// dense f32 — the FedAvg baseline
    None,
    /// keep the k largest-magnitude coordinates (sparsification)
    TopK { ratio: f64 },
    /// keep k *random* coordinates (cheaper, unbiased when rescaled)
    RandK { ratio: f64 },
    /// per-chunk affine int8 quantization with stochastic rounding
    Int8,
    /// f32 -> f16 truncation (2x)
    Fp16,
}

impl Compression {
    pub fn name(&self) -> &'static str {
        match self {
            Compression::None => "none",
            Compression::TopK { .. } => "topk",
            Compression::RandK { .. } => "randk",
            Compression::Int8 => "int8",
            Compression::Fp16 => "fp16",
        }
    }

    pub fn parse(s: &str) -> Option<Compression> {
        let s = s.to_ascii_lowercase();
        if s == "none" {
            Some(Compression::None)
        } else if s == "int8" {
            Some(Compression::Int8)
        } else if s == "fp16" {
            Some(Compression::Fp16)
        } else if let Some(r) = s.strip_prefix("topk:") {
            r.parse().ok().map(|ratio| Compression::TopK { ratio })
        } else if let Some(r) = s.strip_prefix("randk:") {
            r.parse().ok().map(|ratio| Compression::RandK { ratio })
        } else {
            None
        }
    }

    /// Bytes of wire header needed to reconstruct this scheme alongside
    /// the payload: scheme tag (1) + element count (8), plus the ratio
    /// (f64, 8) for the parametrized sparse schemes — the ratio is part of
    /// the scheme and must be counted (it was previously omitted).
    pub fn header_bytes(&self) -> u64 {
        match self {
            Compression::TopK { .. } | Compression::RandK { .. } => 17,
            _ => 9,
        }
    }
}

/// A compressed update: opaque bytes + the codec needed to reopen them.
#[derive(Clone, Debug)]
pub struct CompressedPayload {
    pub scheme: Compression,
    /// lossless byte stage the data went through after `scheme`
    /// (`None` = legacy unframed bytes)
    pub stage: LosslessStage,
    pub n: usize,
    pub data: Vec<u8>,
}

impl CompressedPayload {
    pub fn byte_len(&self) -> u64 {
        self.data.len() as u64 + self.scheme.header_bytes()
    }
}

/// Round-persistent encode workspace owned by [`Compressor`] — replaces
/// the per-call index/value `Vec` churn in the sparse schemes.
#[derive(Clone, Debug, Default)]
struct CodecScratch {
    /// index workspace for top-k selection / rand-k sampling (u32 halves
    /// the footprint vs `usize` and matches the wire format)
    idx: Vec<u32>,
}

/// Stateful compressor (owns the RNG for stochastic schemes and the
/// encode scratch).
#[derive(Clone, Debug)]
pub struct Compressor {
    pub scheme: Compression,
    /// lossless byte stage applied after `scheme` on encode, stripped
    /// before it on decode (`None` = legacy unframed layout)
    pub lossless: LosslessStage,
    rng: Pcg64,
    scratch: CodecScratch,
    /// staged-encode scratch: the lossy codec writes here, the lossless
    /// stage reads it back (round-persistent, no steady-state alloc)
    stage_buf: Vec<u8>,
}

const INT8_CHUNK: usize = 4096;

impl Compressor {
    pub fn new(scheme: Compression, seed: u64) -> Compressor {
        Compressor {
            scheme,
            lossless: LosslessStage::None,
            rng: Pcg64::new(seed, 0xC0DEC),
            scratch: CodecScratch::default(),
            stage_buf: Vec::new(),
        }
    }

    /// Attach a lossless byte stage (builder form so `new` keeps its
    /// signature; `None` is the default and changes nothing).
    pub fn with_lossless(mut self, stage: LosslessStage) -> Compressor {
        self.lossless = stage;
        self
    }

    /// Compress a flat vector. Exactly reversible layout via `decompress`.
    pub fn compress(&mut self, xs: &[f32]) -> CompressedPayload {
        let mut data = Vec::with_capacity(self.encoded_size_hint(xs.len()));
        self.compress_append(xs, &mut data);
        CompressedPayload {
            scheme: self.scheme,
            stage: self.lossless,
            n: xs.len(),
            data,
        }
    }

    fn encoded_size_hint(&self, n: usize) -> usize {
        match self.scheme {
            Compression::None => n * 4,
            Compression::Fp16 => n * 2,
            Compression::Int8 => n + n.div_ceil(INT8_CHUNK) * 8,
            Compression::TopK { ratio } | Compression::RandK { ratio } => {
                4 + k_of(n, ratio) * 8
            }
        }
    }

    /// Append the compressed image of `xs` to `out` — the zero-copy entry
    /// point the transport pipeline uses to build its frame in place.
    /// Writes directly into the output buffer (no intermediate index or
    /// value vectors) and parallelizes per block. With a lossless stage
    /// attached, the lossy codec encodes into compressor-owned scratch
    /// and the staged frame lands in `out`; without one the bytes are
    /// identical to before the stage existed. Returns the number of
    /// bytes appended.
    pub fn compress_append(&mut self, xs: &[f32], out: &mut Vec<u8>) -> usize {
        if self.lossless.is_none() {
            return self.lossy_append(xs, out);
        }
        // take/put keeps the borrows of self disjoint
        let mut inner = std::mem::take(&mut self.stage_buf);
        inner.clear();
        self.lossy_append(xs, &mut inner);
        let n = lossless::encode_append(self.lossless, &inner, out);
        self.stage_buf = inner;
        n
    }

    /// The lossy codec pass (everything below the lossless stage).
    fn lossy_append(&mut self, xs: &[f32], out: &mut Vec<u8>) -> usize {
        let start = out.len();
        match self.scheme {
            Compression::None => {
                out.resize(start + xs.len() * 4, 0);
                f32s_to_le_into(xs, &mut out[start..]);
            }
            Compression::Fp16 => {
                out.resize(start + xs.len() * 2, 0);
                let dst = &mut out[start..];
                let items: Vec<(&mut [u8], &[f32])> = dst
                    .chunks_mut(par::BLOCK * 2)
                    .zip(xs.chunks(par::BLOCK))
                    .collect();
                par::run_items_auto(xs.len(), items, |(d, s)| {
                    for (db, &x) in d.chunks_exact_mut(2).zip(s) {
                        db.copy_from_slice(&f32_to_f16_bits(x).to_le_bytes());
                    }
                });
            }
            Compression::Int8 => int8_append(xs, &mut self.rng, out),
            Compression::TopK { ratio } => {
                let k = k_of(xs.len(), ratio);
                top_k_into(xs, k, &mut self.scratch.idx);
                sparse_append(xs, &self.scratch.idx, 1.0, out);
            }
            Compression::RandK { ratio } => {
                let k = k_of(xs.len(), ratio);
                sample_indices_into(&mut self.rng, xs.len(), k, &mut self.scratch.idx);
                // unbiased: scale kept coords by n/k
                let scale = xs.len() as f32 / k.max(1) as f32;
                sparse_append(xs, &self.scratch.idx, scale, out);
            }
        }
        out.len() - start
    }

    /// Decompress back to a dense vector of length `payload.n`.
    pub fn decompress(payload: &CompressedPayload) -> Result<Vec<f32>> {
        let mut out = vec![0.0f32; payload.n];
        let mut scratch = Vec::new();
        Self::decompress_staged_into(
            payload.scheme,
            payload.stage,
            &payload.data,
            &mut scratch,
            &mut out,
        )?;
        Ok(out)
    }

    /// [`Compressor::decompress_into`] for frames that went through a
    /// lossless stage: strips the stage into `scratch` first, then runs
    /// the lossy decode. `LosslessStage::None` is a straight passthrough
    /// (legacy unframed bytes, zero extra work).
    pub fn decompress_staged_into(
        scheme: Compression,
        stage: LosslessStage,
        data: &[u8],
        scratch: &mut Vec<u8>,
        out: &mut [f32],
    ) -> Result<()> {
        if stage.is_none() {
            return Self::decompress_into(scheme, data, out);
        }
        lossless::decode_into(data, scratch)?;
        Self::decompress_into(scheme, scratch, out)
    }

    /// Decompress raw payload bytes into a caller-sized buffer
    /// (`out.len()` is the element count) — the transport pipeline's
    /// allocation-free entry point. Parallel for the dense codecs.
    pub fn decompress_into(
        scheme: Compression,
        data: &[u8],
        out: &mut [f32],
    ) -> Result<()> {
        let n = out.len();
        match scheme {
            Compression::None => {
                if data.len() != n * 4 {
                    bail!(
                        "dense payload length {} bytes != {} elems",
                        data.len(),
                        n
                    );
                }
                le_to_f32s_into(data, out).expect("length checked");
            }
            Compression::Fp16 => {
                if data.len() != n * 2 {
                    bail!("fp16 payload length mismatch");
                }
                let items: Vec<(&mut [f32], &[u8])> = out
                    .chunks_mut(par::BLOCK)
                    .zip(data.chunks(par::BLOCK * 2))
                    .collect();
                par::run_items_auto(n, items, |(d, s)| {
                    for (x, c) in d.iter_mut().zip(s.chunks_exact(2)) {
                        *x = f16_bits_to_f32(u16::from_le_bytes([c[0], c[1]]));
                    }
                });
            }
            Compression::Int8 => int8_decode_into(data, out)?,
            Compression::TopK { .. } | Compression::RandK { .. } => {
                sparse_decode_into(data, out)?;
            }
        }
        Ok(())
    }

    /// Stochastic-scheme RNG state (WAL snapshot; the scratch buffers are
    /// derived per call and carry no state across rounds).
    pub fn rng_state(&self) -> [u64; 4] {
        self.rng.state_words()
    }

    /// Restore the RNG (WAL resume) so int8 rounding / RandK sampling
    /// continue their exact streams.
    pub fn restore_rng(&mut self, state: [u64; 4]) {
        self.rng = Pcg64::from_state_words(state);
    }

    /// Compression ratio estimate (payload bytes / dense bytes).
    pub fn ratio_estimate(&self, n: usize) -> f64 {
        if n == 0 {
            return 1.0;
        }
        let dense = (n * 4) as f64;
        match self.scheme {
            Compression::None => 1.0,
            Compression::Fp16 => 0.5,
            Compression::Int8 => (n as f64 + (n.div_ceil(INT8_CHUNK) * 8) as f64) / dense,
            Compression::TopK { ratio } | Compression::RandK { ratio } => {
                (k_of(n, ratio) * 8) as f64 / dense
            }
        }
    }
}

fn k_of(n: usize, ratio: f64) -> usize {
    if n == 0 {
        return 0; // empty leaf: nothing to keep (clamp(1, 0) would panic)
    }
    ((n as f64 * ratio).round() as usize).clamp(1, n)
}

/// Fill `idx` with the k largest-|x| indices (O(n) select, scratch-reused
/// across rounds — no per-call allocation once warm).
fn top_k_into(xs: &[f32], k: usize, idx: &mut Vec<u32>) {
    idx.clear();
    idx.extend(0..xs.len() as u32);
    if k < xs.len() {
        idx.select_nth_unstable_by(k - 1, |&a, &b| {
            xs[b as usize].abs().partial_cmp(&xs[a as usize].abs()).unwrap()
        });
        idx.truncate(k);
    }
}

/// Partial Fisher–Yates into scratch: same draw sequence as
/// `Pcg64::sample_indices` (k draws of `below(n-i)`), no allocation once
/// the permutation buffer is warm.
fn sample_indices_into(rng: &mut Pcg64, n: usize, k: usize, idx: &mut Vec<u32>) {
    assert!(k <= n);
    idx.clear();
    idx.extend(0..n as u32);
    for i in 0..k {
        let j = i + rng.below((n - i) as u64) as usize;
        idx.swap(i, j);
    }
    idx.truncate(k);
}

/// layout: [k u32 count][k u32 indices][k f32 values] — written straight
/// into `out`; the index/value gather is block-parallel.
fn sparse_append(xs: &[f32], idx: &[u32], scale: f32, out: &mut Vec<u8>) {
    let k = idx.len();
    let start = out.len();
    out.resize(start + 4 + k * 8, 0);
    let (cnt, rest) = out[start..].split_at_mut(4);
    cnt.copy_from_slice(&(k as u32).to_le_bytes());
    let (ib, vb) = rest.split_at_mut(k * 4);
    let items: Vec<((&[u32], &mut [u8]), &mut [u8])> = idx
        .chunks(par::BLOCK)
        .zip(ib.chunks_mut(par::BLOCK * 4))
        .zip(vb.chunks_mut(par::BLOCK * 4))
        .collect();
    par::run_items_auto(k, items, |((is, ibc), vbc)| {
        for ((&i, i4), v4) in is
            .iter()
            .zip(ibc.chunks_exact_mut(4))
            .zip(vbc.chunks_exact_mut(4))
        {
            i4.copy_from_slice(&i.to_le_bytes());
            v4.copy_from_slice(&(xs[i as usize] * scale).to_le_bytes());
        }
    });
}

fn sparse_decode_into(data: &[u8], out: &mut [f32]) -> Result<()> {
    let n = out.len();
    if data.len() < 4 {
        bail!("sparse payload too short");
    }
    let k = u32::from_le_bytes([data[0], data[1], data[2], data[3]]) as usize;
    let want = 4 + k * 8;
    if data.len() != want {
        bail!("sparse payload length {} != {}", data.len(), want);
    }
    out.fill(0.0);
    let ib = &data[4..4 + 4 * k];
    let vb = &data[4 + 4 * k..];
    for (i4, v4) in ib.chunks_exact(4).zip(vb.chunks_exact(4)) {
        let i = u32::from_le_bytes([i4[0], i4[1], i4[2], i4[3]]) as usize;
        if i >= n {
            bail!("sparse index {i} out of range {n}");
        }
        out[i] = f32::from_le_bytes([v4[0], v4[1], v4[2], v4[3]]);
    }
    Ok(())
}

/// int8: per-chunk [min f32][scale f32][n_chunk u8 codes] with stochastic
/// rounding so quantization is unbiased in expectation.
///
/// Chunks are encoded in parallel; each chunk's rounding stream is a
/// `Pcg64` seeded from one serial draw of the compressor RNG, so the
/// output is a pure function of the RNG state — identical for any thread
/// count.
fn int8_append(xs: &[f32], rng: &mut Pcg64, out: &mut Vec<u8>) {
    let nchunks = xs.len().div_ceil(INT8_CHUNK);
    let seeds: Vec<u64> = (0..nchunks).map(|_| rng.next_u64()).collect();
    let start = out.len();
    out.resize(start + xs.len() + nchunks * 8, 0);
    let dst = &mut out[start..];
    let items: Vec<((&[f32], &mut [u8]), &u64)> = xs
        .chunks(INT8_CHUNK)
        .zip(dst.chunks_mut(INT8_CHUNK + 8))
        .zip(seeds.iter())
        .collect();
    par::run_items_auto(xs.len(), items, |((chunk, d), &seed)| {
        int8_encode_chunk(chunk, seed, d);
    });
}

fn int8_encode_chunk(chunk: &[f32], seed: u64, d: &mut [u8]) {
    debug_assert_eq!(d.len(), chunk.len() + 8);
    let lo = chunk.iter().cloned().fold(f32::INFINITY, f32::min);
    let hi = chunk.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let scale = if hi > lo { (hi - lo) / 255.0 } else { 0.0 };
    d[0..4].copy_from_slice(&lo.to_le_bytes());
    d[4..8].copy_from_slice(&scale.to_le_bytes());
    let codes = &mut d[8..];
    if scale == 0.0 {
        codes.fill(0);
        return;
    }
    let mut rng = Pcg64::new(seed, 0x1A7E8);
    // perf (EXPERIMENTS.md §Perf): hoist 1/scale, draw two random
    // lanes per PRNG step, keep the loop branch-light
    let inv_scale = 1.0 / scale;
    let mut i = 0;
    while i < chunk.len() {
        let r = rng.next_u64();
        let r0 = ((r >> 40) as u32) as f32 * (1.0 / (1u32 << 24) as f32);
        let r1 =
            (((r >> 8) & 0xff_ffff) as u32) as f32 * (1.0 / (1u32 << 24) as f32);
        for ((x, rnd), c) in chunk[i..chunk.len().min(i + 2)]
            .iter()
            .zip([r0, r1])
            .zip(codes[i..].iter_mut())
        {
            let exact = (x - lo) * inv_scale;
            let base = exact.floor();
            let code = base + f32::from(rnd < exact - base);
            *c = code.clamp(0.0, 255.0) as u8;
        }
        i += 2;
    }
}

fn int8_decode_into(data: &[u8], out: &mut [f32]) -> Result<()> {
    let n = out.len();
    let nchunks = n.div_ceil(INT8_CHUNK);
    let want = n + nchunks * 8;
    if data.len() != want {
        bail!("int8 payload length {} != {}", data.len(), want);
    }
    let items: Vec<(&[u8], &mut [f32])> = data
        .chunks(INT8_CHUNK + 8)
        .zip(out.chunks_mut(INT8_CHUNK))
        .collect();
    par::run_items_auto(n, items, |(d, o)| {
        let lo = f32::from_le_bytes([d[0], d[1], d[2], d[3]]);
        let scale = f32::from_le_bytes([d[4], d[5], d[6], d[7]]);
        for (x, &b) in o.iter_mut().zip(&d[8..]) {
            *x = lo + scale * b as f32;
        }
    });
    Ok(())
}

// ---- f16 conversion (no `half` crate offline) -----------------------------

pub(crate) fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let mut exp = ((bits >> 23) & 0xff) as i32;
    let mut frac = bits & 0x007f_ffff;
    if exp == 0xff {
        // inf / nan
        return sign | 0x7c00 | if frac != 0 { 0x200 } else { 0 };
    }
    exp = exp - 127 + 15;
    if exp >= 0x1f {
        return sign | 0x7c00; // overflow -> inf
    }
    if exp <= 0 {
        // subnormal or zero
        if exp < -10 {
            return sign;
        }
        frac |= 0x0080_0000;
        let shift = 14 - exp;
        let half = frac >> shift;
        // round to nearest even
        let rem = frac & ((1 << shift) - 1);
        let halfway = 1u32 << (shift - 1);
        let rounded = match rem.cmp(&halfway) {
            std::cmp::Ordering::Greater => half + 1,
            std::cmp::Ordering::Equal => half + (half & 1),
            std::cmp::Ordering::Less => half,
        };
        return sign | rounded as u16;
    }
    // normal: round mantissa 23 -> 10 bits, nearest even
    let half = frac >> 13;
    let rem = frac & 0x1fff;
    let mut out = ((exp as u32) << 10) | half;
    match rem.cmp(&0x1000) {
        std::cmp::Ordering::Greater => out += 1,
        std::cmp::Ordering::Equal => out += out & 1,
        std::cmp::Ordering::Less => {}
    }
    sign | out as u16
}

pub(crate) fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1f) as u32;
    let frac = (h & 0x3ff) as u32;
    let bits = if exp == 0 {
        if frac == 0 {
            sign
        } else {
            // subnormal: normalize
            let mut e = -1i32;
            let mut f = frac;
            while f & 0x400 == 0 {
                f <<= 1;
                e -= 1;
            }
            f &= 0x3ff;
            sign | (((127 - 15 + e + 1) as u32) << 23) | (f << 13)
        }
    } else if exp == 0x1f {
        sign | 0x7f80_0000 | (frac << 13)
    } else {
        sign | ((exp + 127 - 15) << 23) | (frac << 13)
    };
    f32::from_bits(bits)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Pcg64::new(seed, 1);
        (0..n).map(|_| rng.normal_ms(0.0, 0.1) as f32).collect()
    }

    #[test]
    fn none_roundtrips_exactly() {
        let xs = sample(1000, 1);
        let mut c = Compressor::new(Compression::None, 0);
        let p = c.compress(&xs);
        assert_eq!(Compressor::decompress(&p).unwrap(), xs);
        assert_eq!(p.byte_len(), 4009);
    }

    #[test]
    fn fp16_halves_and_approximates() {
        let xs = sample(1000, 2);
        let mut c = Compressor::new(Compression::Fp16, 0);
        let p = c.compress(&xs);
        assert_eq!(p.data.len(), 2000);
        let ys = Compressor::decompress(&p).unwrap();
        for (x, y) in xs.iter().zip(&ys) {
            assert!((x - y).abs() < 2e-3 * x.abs().max(0.1), "{x} vs {y}");
        }
    }

    #[test]
    fn f16_special_values() {
        for x in [0.0f32, -0.0, 1.0, -1.0, 65504.0, 1e-7, f32::INFINITY] {
            let y = f16_bits_to_f32(f32_to_f16_bits(x));
            if x.is_finite() && x.abs() > 1e-4 {
                assert!((x - y).abs() / x.abs().max(1e-3) < 1e-3, "{x} -> {y}");
            }
        }
        assert!(f16_bits_to_f32(f32_to_f16_bits(f32::NAN)).is_nan());
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(1e9)), f32::INFINITY);
    }

    #[test]
    fn topk_keeps_largest() {
        let xs = vec![0.1, -5.0, 0.2, 3.0, -0.05, 0.0, 1.0, -0.3];
        let mut c = Compressor::new(Compression::TopK { ratio: 0.25 }, 0);
        let p = c.compress(&xs);
        let ys = Compressor::decompress(&p).unwrap();
        assert_eq!(ys[1], -5.0);
        assert_eq!(ys[3], 3.0);
        assert_eq!(ys.iter().filter(|&&y| y != 0.0).count(), 2);
    }

    #[test]
    fn topk_payload_smaller() {
        let xs = sample(10_000, 3);
        let mut c = Compressor::new(Compression::TopK { ratio: 0.01 }, 0);
        let p = c.compress(&xs);
        assert!(p.byte_len() < 2000, "{}", p.byte_len());
        assert!((c.ratio_estimate(10_000) - 0.02).abs() < 0.01);
    }

    #[test]
    fn randk_unbiased_in_expectation() {
        let xs = vec![1.0f32; 512];
        let mut c = Compressor::new(Compression::RandK { ratio: 0.25 }, 7);
        let mut acc = vec![0.0f64; 512];
        let trials = 400;
        for _ in 0..trials {
            let ys = Compressor::decompress(&c.compress(&xs)).unwrap();
            for (a, y) in acc.iter_mut().zip(&ys) {
                *a += *y as f64;
            }
        }
        let mean: f64 = acc.iter().sum::<f64>() / (512.0 * trials as f64);
        assert!((mean - 1.0).abs() < 0.05, "mean={mean}");
    }

    #[test]
    fn int8_bounded_error_and_unbiased() {
        let xs = sample(8192, 4);
        let mut c = Compressor::new(Compression::Int8, 5);
        let p = c.compress(&xs);
        // ~1 byte/elem + 8B header per 4096 chunk
        assert!(p.data.len() <= 8192 + 2 * 8);
        let ys = Compressor::decompress(&p).unwrap();
        let span = {
            let lo = xs.iter().cloned().fold(f32::INFINITY, f32::min);
            let hi = xs.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            hi - lo
        };
        let step = span / 255.0;
        let mut bias = 0.0f64;
        for (x, y) in xs.iter().zip(&ys) {
            assert!((x - y).abs() <= step * 1.001, "{x} vs {y}");
            bias += (*y - *x) as f64;
        }
        assert!(bias.abs() / 8192.0 < step as f64 * 0.1, "bias={bias}");
    }

    #[test]
    fn int8_constant_chunk() {
        let xs = vec![3.5f32; 100];
        let mut c = Compressor::new(Compression::Int8, 6);
        let ys = Compressor::decompress(&c.compress(&xs)).unwrap();
        assert_eq!(ys, xs);
    }

    #[test]
    fn parse_schemes() {
        assert_eq!(Compression::parse("none"), Some(Compression::None));
        assert_eq!(
            Compression::parse("topk:0.01"),
            Some(Compression::TopK { ratio: 0.01 })
        );
        assert_eq!(
            Compression::parse("randk:0.1"),
            Some(Compression::RandK { ratio: 0.1 })
        );
        assert_eq!(Compression::parse("int8"), Some(Compression::Int8));
        assert_eq!(Compression::parse("zstd"), None);
    }

    #[test]
    fn corrupt_payloads_rejected() {
        let xs = sample(100, 8);
        let mut c = Compressor::new(Compression::TopK { ratio: 0.1 }, 0);
        let mut p = c.compress(&xs);
        p.data.truncate(p.data.len() - 1);
        assert!(Compressor::decompress(&p).is_err());

        let mut c2 = Compressor::new(Compression::Int8, 0);
        let mut p2 = c2.compress(&xs);
        p2.data.push(0);
        assert!(Compressor::decompress(&p2).is_err());
    }

    #[test]
    fn header_bytes_pinned_per_scheme() {
        // the wire header is scheme tag (1) + element count (8), plus the
        // ratio (8) for the parametrized sparse schemes
        assert_eq!(Compression::None.header_bytes(), 9);
        assert_eq!(Compression::Fp16.header_bytes(), 9);
        assert_eq!(Compression::Int8.header_bytes(), 9);
        assert_eq!(Compression::TopK { ratio: 0.1 }.header_bytes(), 17);
        assert_eq!(Compression::RandK { ratio: 0.1 }.header_bytes(), 17);

        let xs = sample(100, 11);
        for scheme in [
            Compression::None,
            Compression::Fp16,
            Compression::Int8,
            Compression::TopK { ratio: 0.1 },
            Compression::RandK { ratio: 0.1 },
        ] {
            let p = Compressor::new(scheme, 0).compress(&xs);
            assert_eq!(
                p.byte_len(),
                p.data.len() as u64 + scheme.header_bytes(),
                "{scheme:?}"
            );
        }
    }

    #[test]
    fn compress_append_writes_in_place_and_matches_compress() {
        let xs1 = sample(5000, 21);
        let xs2 = sample(301, 22);
        for scheme in [
            Compression::None,
            Compression::Fp16,
            Compression::Int8,
            Compression::TopK { ratio: 0.02 },
            Compression::RandK { ratio: 0.02 },
        ] {
            // twin compressors, same seed: one appends into a dirty shared
            // buffer, one allocates per call — bytes must agree, and the
            // scratch reuse across two different-length inputs must not
            // change anything
            let mut append = Compressor::new(scheme, 9);
            let mut fresh = Compressor::new(scheme, 9);
            let mut buf = vec![0xAAu8; 13];
            let n1 = append.compress_append(&xs1, &mut buf);
            let p1 = fresh.compress(&xs1);
            assert_eq!(&buf[13..13 + n1], &p1.data[..], "{scheme:?}");
            let n2 = append.compress_append(&xs2, &mut buf);
            let p2 = fresh.compress(&xs2);
            assert_eq!(&buf[13 + n1..13 + n1 + n2], &p2.data[..], "{scheme:?}");
            assert!(buf[..13].iter().all(|&b| b == 0xAA), "prefix clobbered");

            // decompress_into agrees with decompress
            let mut out = vec![7.0f32; xs1.len()];
            Compressor::decompress_into(scheme, &p1.data, &mut out).unwrap();
            assert_eq!(out, Compressor::decompress(&p1).unwrap());
        }
    }

    #[test]
    fn lossless_stage_composes_with_every_scheme() {
        let xs = sample(6000, 31);
        for scheme in [
            Compression::None,
            Compression::Fp16,
            Compression::Int8,
            Compression::TopK { ratio: 0.05 },
            Compression::RandK { ratio: 0.05 },
        ] {
            for stage in LosslessStage::ALL {
                // twin compressors, same seed: the staged frame must
                // decode to exactly what the unstaged one decodes to
                // (bit-exact — the stage is lossless by construction)
                let mut plain = Compressor::new(scheme, 77);
                let mut staged = Compressor::new(scheme, 77).with_lossless(stage);
                let p = plain.compress(&xs);
                let s = staged.compress(&xs);
                assert_eq!(s.stage, stage);
                let a = Compressor::decompress(&p).unwrap();
                let b = Compressor::decompress(&s).unwrap();
                let a_bits: Vec<u32> = a.iter().map(|x| x.to_bits()).collect();
                let b_bits: Vec<u32> = b.iter().map(|x| x.to_bits()).collect();
                assert_eq!(a_bits, b_bits, "{scheme:?} + {stage:?}");
            }
        }
    }

    #[test]
    fn lossless_none_is_byte_identical_to_legacy() {
        // `with_lossless(None)` must not perturb a single byte — the
        // pinned payload sizes all over the test suite depend on it
        let xs = sample(2048, 32);
        for scheme in [Compression::None, Compression::Int8] {
            let mut a = Compressor::new(scheme, 3);
            let mut b =
                Compressor::new(scheme, 3).with_lossless(LosslessStage::None);
            assert_eq!(a.compress(&xs).data, b.compress(&xs).data);
        }
    }

    #[test]
    fn auto_stage_shrinks_constant_dense_frames() {
        // the mock backend's constant-leaf params are the motivating
        // case: dense f32 frames collapse under the XOR stage
        let xs = vec![2.0f32; 8192];
        let mut c =
            Compressor::new(Compression::None, 0).with_lossless(LosslessStage::Auto);
        let p = c.compress(&xs);
        assert!(
            (p.data.len() as f64) < 8192.0 * 4.0 * 0.1,
            "constant frame did not compress: {} bytes",
            p.data.len()
        );
        let back = Compressor::decompress(&p).unwrap();
        assert_eq!(back, xs);
    }

    #[test]
    fn sample_indices_into_matches_pcg64_sample_indices() {
        // the scratch-based sampler must keep the exact draw sequence of
        // Pcg64::sample_indices (RandK streams are pinned by experiments);
        // this test ties the two implementations together
        let mut r1 = Pcg64::new(5, 9);
        let mut r2 = Pcg64::new(5, 9);
        let reference = r1.sample_indices(100, 17);
        let mut idx = Vec::new();
        sample_indices_into(&mut r2, 100, 17, &mut idx);
        let got: Vec<usize> = idx.iter().map(|&i| i as usize).collect();
        assert_eq!(got, reference);
        assert_eq!(r1.next_u64(), r2.next_u64()); // same post-state
    }

    #[test]
    fn empty_input_roundtrips_all_schemes() {
        for scheme in [
            Compression::None,
            Compression::Fp16,
            Compression::Int8,
            Compression::TopK { ratio: 0.1 },
            Compression::RandK { ratio: 0.1 },
        ] {
            let mut c = Compressor::new(scheme, 0);
            let p = c.compress(&[]);
            assert_eq!(p.n, 0);
            assert_eq!(Compressor::decompress(&p).unwrap(), Vec::<f32>::new());
        }
    }

    #[test]
    fn sparse_rejects_out_of_range_index() {
        let data = {
            let mut d = Vec::new();
            d.extend_from_slice(&1u32.to_le_bytes());
            d.extend_from_slice(&999u32.to_le_bytes());
            d.extend_from_slice(&1.0f32.to_le_bytes());
            d
        };
        let p = CompressedPayload {
            scheme: Compression::TopK { ratio: 0.1 },
            stage: LosslessStage::None,
            n: 10,
            data,
        };
        assert!(Compressor::decompress(&p).is_err());
    }
}

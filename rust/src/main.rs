//! crossfed CLI — the leader entrypoint.
//!
//! `crossfed train --preset paper-fedavg` runs one federated experiment
//! against the AOT artifacts (or `--mock` for the runtime-free backend);
//! `crossfed sweep` regenerates the paper's Tables 1–3. See `crossfed help`.

fn main() {
    crossfed::util::logging::init();
    let args: Vec<String> = std::env::args().skip(1).collect();
    match crossfed::cli::run_cli(&args) {
        Ok(code) => std::process::exit(code),
        Err(e) => {
            eprintln!("error: {e:#}");
            std::process::exit(1);
        }
    }
}

//! Update transport pipeline: serialize → compress → encrypt → WAN.
//!
//! Every worker→leader update and leader→worker broadcast passes through
//! here, so every byte in Table 2's "Communication Overhead" column is a
//! byte this module actually produced (compression output + seal overhead
//! + protocol framing from the netsim).
//!
//! The uplink is one fused compress→encrypt pipeline: the frame (metadata
//! header + compressed payload) is built directly in a round-persistent
//! send buffer, sealed in place, and decoded in place on the receive side
//! — no dense intermediate copy anywhere on the path, and the steady
//! state allocates nothing per round.

use anyhow::{Context, Result};

use crate::compress::{lossless, Compressor, ErrorFeedback, LosslessStage};
use crate::crypto::{open_in_place, seal_in_place, TransportKey, SEAL_OVERHEAD_BYTES};
use crate::model::ParamSet;
use crate::netsim::{NetError, Protocol, TransferStats, Wan, WanScratch};
use crate::util::bytes::f32s_to_le_into;
use crate::util::rng::Pcg64;

/// Update-frame metadata header size: loss f32 (4) + n_samples u64 (8)
/// + weight f64 (8) + element count u32 (4). Keep in sync with the
/// build/parse code in [`Channel::send_update`]; the failover forward
/// pricing (`Coordinator::dense_frame_bytes`) reuses it.
pub const FRAME_HEADER_BYTES: usize = 24;

/// Per-direction transport channel with its compression + crypto state.
pub struct Channel {
    pub src: usize,
    pub dst: usize,
    pub protocol: Protocol,
    pub streams: usize,
    compressor: Compressor,
    error_feedback: Option<ErrorFeedback>,
    /// encryption keys (None = plaintext transport, for the ablation)
    send_key: Option<TransportKey>,
    recv_key: Option<TransportKey>,
    /// cumulative payload bytes (pre-framing, post-compression+seal)
    pub payload_bytes: u64,
    /// round-persistent pipeline buffers (no per-round allocation)
    flat_buf: Vec<f32>,
    frame_buf: Vec<u8>,
    recv_flat: Vec<f32>,
    /// lossless-stage strip buffer for receive-side decodes (recomputed
    /// every call, so it is not part of the WAL'd channel state)
    stage_scratch: Vec<u8>,
}

/// What arrives at the far end, plus the cost of getting it there.
#[derive(Clone, Debug)]
pub struct Delivery {
    /// the decompressed update as the receiver sees it
    pub update: ParamSet,
    /// metadata forwarded alongside
    pub local_loss: f32,
    pub n_samples: usize,
    /// aggregation weight carried with the update (a gateway's partial
    /// aggregate ships its total raw weight z_c; plain worker updates
    /// ship 1.0)
    pub weight: f64,
    /// simulated transfer seconds (incl. handshake/stalls)
    pub secs: f64,
    /// bytes on the wire (payload + framing + retransmits)
    pub wire_bytes: u64,
}

impl Channel {
    /// `secret`: shared transport secret (None disables encryption).
    pub fn new(
        src: usize,
        dst: usize,
        protocol: Protocol,
        streams: usize,
        compressor: Compressor,
        error_feedback: bool,
        n_params: usize,
        secret: Option<&[u8]>,
    ) -> Channel {
        let ef = error_feedback.then(|| ErrorFeedback::new(n_params, true));
        let ctx = format!("{src}->{dst}");
        Channel {
            src,
            dst,
            protocol,
            streams,
            compressor,
            error_feedback: ef,
            send_key: secret.map(|s| TransportKey::derive(s, &ctx)),
            recv_key: secret.map(|s| TransportKey::derive(s, &ctx)),
            payload_bytes: 0,
            flat_buf: Vec::new(),
            frame_buf: Vec::new(),
            recv_flat: Vec::new(),
            stage_scratch: Vec::new(),
        }
    }

    /// Send an update over the WAN: returns what the receiver decodes.
    ///
    /// The pipeline is real end-to-end: the exact bytes produced by
    /// compression (+ sealing) determine both the netsim cost and what is
    /// decompressed on the far side (so lossy compression affects
    /// convergence, not just byte counts).
    pub fn send_update(
        &mut self,
        update: &ParamSet,
        local_loss: f32,
        n_samples: usize,
        weight: f64,
        wan: &mut Wan,
    ) -> Result<Delivery> {
        self.send_update_via(update, local_loss, n_samples, weight, |s, d, b, p, st| {
            wan.transfer(s, d, b, p, st)
        })
    }

    /// [`Channel::send_update`] against a shared `&Wan`: noise comes from
    /// `rng` and warmth/ledger effects land in `scratch` (see
    /// [`Wan::transfer_scoped`]) — the per-cloud parallel round path.
    pub(crate) fn send_update_scoped(
        &mut self,
        update: &ParamSet,
        local_loss: f32,
        n_samples: usize,
        weight: f64,
        wan: &Wan,
        rng: &mut Pcg64,
        scratch: &mut WanScratch,
    ) -> Result<Delivery> {
        self.send_update_via(update, local_loss, n_samples, weight, |s, d, b, p, st| {
            wan.transfer_scoped(s, d, b, p, st, rng, scratch)
        })
    }

    /// The full serialize→compress→encrypt→transfer→decode pipeline,
    /// generic over how the framed bytes cross the WAN.
    fn send_update_via<F>(
        &mut self,
        update: &ParamSet,
        local_loss: f32,
        n_samples: usize,
        weight: f64,
        transfer: F,
    ) -> Result<Delivery>
    where
        F: FnOnce(usize, usize, u64, Protocol, usize) -> Result<TransferStats, NetError>,
    {
        // flatten into the persistent buffer (parallel copy, no fresh
        // allocation once warm)
        self.flat_buf.resize(update.numel(), 0.0);
        update.write_flat(&mut self.flat_buf);

        // frame = metadata header (loss 4 + n_samples 8 + weight 8 +
        // elem count 4) + compressed payload, built straight in the send
        // buffer
        self.frame_buf.clear();
        self.frame_buf.extend_from_slice(&local_loss.to_le_bytes());
        self.frame_buf.extend_from_slice(&(n_samples as u64).to_le_bytes());
        self.frame_buf.extend_from_slice(&weight.to_le_bytes());
        self.frame_buf
            .extend_from_slice(&(self.flat_buf.len() as u32).to_le_bytes());
        match &mut self.error_feedback {
            Some(ef) => {
                ef.compress_append(&self.flat_buf, &mut self.compressor, &mut self.frame_buf)?;
            }
            None => {
                self.compressor.compress_append(&self.flat_buf, &mut self.frame_buf);
            }
        }

        // encrypt in place: the compress→encrypt pipeline touches one
        // buffer end to end, no dense intermediate copy
        let sealed = self
            .send_key
            .as_mut()
            .map(|key| seal_in_place(key, &mut self.frame_buf));
        let n_bytes = self.frame_buf.len() as u64
            + if sealed.is_some() { SEAL_OVERHEAD_BYTES } else { 0 };
        self.payload_bytes += n_bytes;

        let stats = transfer(self.src, self.dst, n_bytes, self.protocol, self.streams)
            .context("update transfer")?;

        // receiver side: verify + decrypt in place (CTR is self-inverse),
        // parse the frame, decompress into the persistent receive buffer
        if let Some((nonce, tag)) = &sealed {
            let key = self.recv_key.as_ref().expect("sealed implies key");
            open_in_place(key, nonce, tag, &mut self.frame_buf)
                .context("transport decrypt")?;
        }
        anyhow::ensure!(
            self.frame_buf.len() >= FRAME_HEADER_BYTES,
            "frame too short"
        );
        let meta_loss = f32::from_le_bytes(self.frame_buf[0..4].try_into().unwrap());
        let meta_n =
            u64::from_le_bytes(self.frame_buf[4..12].try_into().unwrap()) as usize;
        let meta_weight =
            f64::from_le_bytes(self.frame_buf[12..20].try_into().unwrap());
        let n_elems =
            u32::from_le_bytes(self.frame_buf[20..24].try_into().unwrap()) as usize;
        self.recv_flat.resize(n_elems, 0.0);
        Compressor::decompress_staged_into(
            self.compressor.scheme,
            self.compressor.lossless,
            &self.frame_buf[FRAME_HEADER_BYTES..],
            &mut self.stage_scratch,
            &mut self.recv_flat,
        )?;

        let update = ParamSet::from_flat(&self.recv_flat, update)
            .context("decoded update has wrong size")?;
        Ok(Delivery {
            update,
            local_loss: meta_loss,
            n_samples: meta_n,
            weight: meta_weight,
            secs: stats.time_s,
            wire_bytes: stats.wire_bytes,
        })
    }

    /// Run an update through this channel's codec (+ error feedback)
    /// *without* a WAN or encrypt hop — the leader-colocated loopback
    /// path. The result is exactly what a remote peer would decode, so
    /// aggregation sees uniformly-compressed updates regardless of where
    /// a worker sits. No bytes are charged.
    pub fn codec_loopback(&mut self, update: &ParamSet) -> Result<ParamSet> {
        self.flat_buf.resize(update.numel(), 0.0);
        update.write_flat(&mut self.flat_buf);
        self.frame_buf.clear();
        match &mut self.error_feedback {
            Some(ef) => {
                ef.compress_append(&self.flat_buf, &mut self.compressor, &mut self.frame_buf)?;
            }
            None => {
                self.compressor.compress_append(&self.flat_buf, &mut self.frame_buf);
            }
        }
        self.recv_flat.resize(self.flat_buf.len(), 0.0);
        Compressor::decompress_staged_into(
            self.compressor.scheme,
            self.compressor.lossless,
            &self.frame_buf,
            &mut self.stage_scratch,
            &mut self.recv_flat,
        )?;
        ParamSet::from_flat(&self.recv_flat, update)
            .context("loopback decode has wrong size")
    }

    /// Snapshot this channel's run state for the WAL: the codec RNG, the
    /// error-feedback residual, the sender nonce counter and the byte
    /// accumulator. Identity (src/dst/protocol/streams) and key material
    /// are config — the channel is rebuilt from the run spec on resume
    /// and this state overlaid.
    pub fn wal_encode(&self, w: &mut crate::wal::ByteWriter) {
        w.put_u64x4(self.compressor.rng_state());
        match &self.error_feedback {
            None => w.put_bool(false),
            Some(ef) => {
                w.put_bool(true);
                ef.wal_encode(w);
            }
        }
        match &self.send_key {
            None => w.put_bool(false),
            Some(key) => {
                w.put_bool(true);
                w.put_u64(key.seq());
            }
        }
        w.put_u64(self.payload_bytes);
    }

    /// Restore state written by [`Channel::wal_encode`].
    pub fn wal_decode(
        &mut self,
        r: &mut crate::wal::ByteReader,
    ) -> Result<()> {
        self.compressor.restore_rng(r.get_u64x4()?);
        let had_ef = r.get_bool()?;
        anyhow::ensure!(
            had_ef == self.error_feedback.is_some(),
            "WAL channel {}->{}: error-feedback config changed across resume",
            self.src,
            self.dst
        );
        if let Some(ef) = &mut self.error_feedback {
            ef.wal_decode(r)?;
        }
        let had_key = r.get_bool()?;
        anyhow::ensure!(
            had_key == self.send_key.is_some(),
            "WAL channel {}->{}: encryption config changed across resume",
            self.src,
            self.dst
        );
        if had_key {
            let seq = r.get_u64()?;
            self.send_key.as_mut().expect("checked").set_seq(seq);
        }
        self.payload_bytes = r.get_u64()?;
        Ok(())
    }

    /// Broadcast raw params (dense f32, optionally sealed) to a worker.
    /// Returns (secs, wire_bytes).
    pub fn send_params(
        &mut self,
        params: &ParamSet,
        wan: &mut Wan,
    ) -> Result<(f64, u64)> {
        self.send_params_via(params, |s, d, b, p, st| wan.transfer(s, d, b, p, st))
    }

    /// [`Channel::send_params`] against a shared `&Wan` (see
    /// [`Channel::send_update_scoped`]).
    pub(crate) fn send_params_scoped(
        &mut self,
        params: &ParamSet,
        wan: &Wan,
        rng: &mut Pcg64,
        scratch: &mut WanScratch,
    ) -> Result<(f64, u64)> {
        self.send_params_via(params, |s, d, b, p, st| {
            wan.transfer_scoped(s, d, b, p, st, rng, scratch)
        })
    }

    /// Dense-broadcast pipeline, generic over the WAN leg.
    fn send_params_via<F>(&mut self, params: &ParamSet, transfer: F) -> Result<(f64, u64)>
    where
        F: FnOnce(usize, usize, u64, Protocol, usize) -> Result<TransferStats, NetError>,
    {
        self.flat_buf.resize(params.numel(), 0.0);
        params.write_flat(&mut self.flat_buf);
        encode_dense_payload(
            &self.flat_buf,
            self.compressor.lossless,
            &mut self.stage_scratch,
            &mut self.frame_buf,
        );
        let n_bytes = match &mut self.send_key {
            Some(key) => {
                let (nonce, tag) = seal_in_place(key, &mut self.frame_buf);
                // receiver-side verification (keeps crypto honest); the
                // buffer is plaintext again afterwards
                open_in_place(
                    self.recv_key.as_ref().unwrap(),
                    &nonce,
                    &tag,
                    &mut self.frame_buf,
                )
                .context("broadcast decrypt")?;
                self.frame_buf.len() as u64 + SEAL_OVERHEAD_BYTES
            }
            None => self.frame_buf.len() as u64,
        };
        self.payload_bytes += n_bytes;
        let stats = transfer(self.src, self.dst, n_bytes, self.protocol, self.streams)
            .context("params broadcast transfer")?;
        Ok((stats.time_s, stats.wire_bytes))
    }
}

/// Encode a flat dense f32 payload under `stage` into `out` (cleared
/// first): exactly the broadcast-frame body [`Channel::send_params`]
/// puts on the wire before sealing. `LosslessStage::None` yields the
/// raw little-endian bytes; any other stage yields its lossless frame.
fn encode_dense_payload(
    flat: &[f32],
    stage: LosslessStage,
    scratch: &mut Vec<u8>,
    out: &mut Vec<u8>,
) {
    if stage.is_none() {
        out.clear();
        out.resize(flat.len() * 4, 0);
        f32s_to_le_into(flat, out);
        return;
    }
    scratch.clear();
    scratch.resize(flat.len() * 4, 0);
    f32s_to_le_into(flat, scratch);
    out.clear();
    lossless::encode_append(stage, scratch, out);
}

/// Exact dense-broadcast payload size (pre-seal) for `params` under
/// `stage`. This is the single source of truth shared by the training
/// broadcast ([`Channel::send_params`]) and the serve checkpoint-refresh
/// maths (`ServeConfig::with_checkpoint`), so a lossless stage reprices
/// both consistently.
pub fn dense_payload_bytes(params: &ParamSet, stage: LosslessStage) -> u64 {
    if stage.is_none() {
        return dense_param_bytes(params.numel() as u64);
    }
    let mut flat = vec![0.0f32; params.numel()];
    params.write_flat(&mut flat);
    let (mut scratch, mut out) = (Vec::new(), Vec::new());
    encode_dense_payload(&flat, stage, &mut scratch, &mut out);
    out.len() as u64
}

/// Raw dense parameter bytes (`numel × 4`) — the value-independent size
/// used where only a parameter *count* is known (CLI `--model-params`,
/// failover forward pricing).
pub fn dense_param_bytes(numel: u64) -> u64 {
    numel * 4
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::Compression;
    use crate::netsim::Link;

    fn wan() -> Wan {
        Wan::uniform(3, Link::new(1e9, 0.02), 7)
    }

    fn update(n: usize) -> ParamSet {
        ParamSet {
            leaves: vec![(0..n).map(|i| (i as f32 * 0.01).sin()).collect()],
        }
    }

    fn channel(compression: Compression, encrypted: bool) -> Channel {
        Channel::new(
            1,
            0,
            Protocol::Grpc,
            8,
            Compressor::new(compression, 3),
            matches!(compression, Compression::TopK { .. }),
            256,
            encrypted.then_some(b"secret".as_slice()),
        )
    }

    #[test]
    fn dense_encrypted_roundtrip() {
        let mut ch = channel(Compression::None, true);
        let mut w = wan();
        let u = update(256);
        let d = ch.send_update(&u, 1.25, 999, 7.5, &mut w).unwrap();
        assert_eq!(d.update, u); // lossless end-to-end
        assert_eq!(d.local_loss, 1.25);
        assert_eq!(d.n_samples, 999);
        assert_eq!(d.weight, 7.5);
        assert!(d.secs > 0.0);
        // sealed: 256*4 + 24 header + 48 seal
        assert_eq!(ch.payload_bytes, 256 * 4 + 24 + 48);
    }

    #[test]
    fn plaintext_skips_seal_overhead() {
        let mut enc = channel(Compression::None, true);
        let mut plain = channel(Compression::None, false);
        let mut w = wan();
        let u = update(256);
        enc.send_update(&u, 0.0, 1, 1.0, &mut w).unwrap();
        plain.send_update(&u, 0.0, 1, 1.0, &mut w).unwrap();
        assert_eq!(enc.payload_bytes - plain.payload_bytes, 48);
    }

    #[test]
    fn topk_shrinks_wire_bytes_and_loses_info() {
        let mut dense = channel(Compression::None, true);
        let mut sparse = channel(Compression::TopK { ratio: 0.05 }, true);
        let mut w = wan();
        let u = update(256);
        let dd = dense.send_update(&u, 0.0, 1, 1.0, &mut w).unwrap();
        let ds = sparse.send_update(&u, 0.0, 1, 1.0, &mut w).unwrap();
        assert!(sparse.payload_bytes < dense.payload_bytes / 5);
        assert!(ds.wire_bytes < dd.wire_bytes / 5);
        // lossy: only some coords survive
        let nonzero = ds.update.leaves[0].iter().filter(|&&x| x != 0.0).count();
        assert!(nonzero <= 13);
        assert_eq!(dd.update, u);
    }

    #[test]
    fn broadcast_counts_bytes() {
        let mut ch = channel(Compression::None, true);
        let mut w = wan();
        let (secs, wire) = ch.send_params(&update(256), &mut w).unwrap();
        assert!(secs > 0.0);
        assert!(wire >= 256 * 4);
    }

    #[test]
    fn wire_bytes_exceed_payload_bytes() {
        // framing overhead must show up in the ledger
        let mut ch = channel(Compression::None, false);
        let mut w = wan();
        let d = ch.send_update(&update(1024), 0.0, 1, 1.0, &mut w).unwrap();
        assert!(d.wire_bytes > ch.payload_bytes);
    }

    #[test]
    fn codec_loopback_matches_remote_decode() {
        // the leader-colocated worker's update must go through the same
        // codec as everyone else's — compare against a WAN delivery from
        // an identically-configured channel
        let u = update(256);
        let mut w = wan();
        let mut remote = channel(Compression::TopK { ratio: 0.05 }, true);
        let d = remote.send_update(&u, 0.0, 1, 1.0, &mut w).unwrap();
        let mut local = channel(Compression::TopK { ratio: 0.05 }, true);
        let lb = local.codec_loopback(&u).unwrap();
        assert_eq!(lb, d.update); // identical lossy decode
        assert_eq!(local.payload_bytes, 0); // loopback charges nothing

        // lossless codec: loopback is the identity
        let mut dense = channel(Compression::None, false);
        assert_eq!(dense.codec_loopback(&u).unwrap(), u);
    }

    fn staged_channel(stage: LosslessStage, encrypted: bool) -> Channel {
        Channel::new(
            1,
            0,
            Protocol::Grpc,
            8,
            Compressor::new(Compression::None, 3).with_lossless(stage),
            false,
            256,
            encrypted.then_some(b"secret".as_slice()),
        )
    }

    #[test]
    fn staged_channel_roundtrips_and_shrinks_payload() {
        // a near-constant dense update collapses under the stage; decode
        // stays bit-exact and payload_bytes sees post-lossless sizes
        let u = ParamSet {
            leaves: vec![vec![1.5f32; 256]],
        };
        let mut w = wan();
        let mut plain = channel(Compression::None, true);
        let mut staged = staged_channel(LosslessStage::Auto, true);
        let dp = plain.send_update(&u, 0.1, 5, 1.0, &mut w).unwrap();
        let ds = staged.send_update(&u, 0.1, 5, 1.0, &mut w).unwrap();
        assert_eq!(ds.update, u);
        assert_eq!(ds.update, dp.update);
        assert!(
            staged.payload_bytes < plain.payload_bytes / 4,
            "staged={} plain={}",
            staged.payload_bytes,
            plain.payload_bytes
        );
        // loopback composes with the stage too
        assert_eq!(staged.codec_loopback(&u).unwrap(), u);
        // and a sine-ramp update survives every stage exactly
        let ramp = update(256);
        for stage in LosslessStage::ALL {
            let mut ch = staged_channel(stage, false);
            let d = ch.send_update(&ramp, 0.0, 1, 1.0, &mut w).unwrap();
            assert_eq!(d.update, ramp, "{stage:?}");
        }
    }

    #[test]
    fn staged_broadcast_matches_payload_accessor() {
        // broadcast pricing and the serve-side accessor must agree exactly
        let u = ParamSet {
            leaves: vec![vec![2.0f32; 192], vec![-1.0f32; 64]],
        };
        for stage in LosslessStage::ALL {
            let mut ch = staged_channel(stage, false);
            let mut w = wan();
            ch.send_params(&u, &mut w).unwrap();
            assert_eq!(ch.payload_bytes, dense_payload_bytes(&u, stage), "{stage:?}");
        }
        // never expands past the raw-frame tag, and a constant-ish model
        // shrinks hard under Auto
        let auto = dense_payload_bytes(&u, LosslessStage::Auto);
        assert!(auto <= dense_param_bytes(256) + lossless::RAW_FRAME_OVERHEAD as u64);
        assert!(auto < dense_param_bytes(256) / 4, "{auto}");
        assert_eq!(dense_param_bytes(256), 1024);
    }

    #[test]
    fn scoped_send_matches_direct_send() {
        // the parallel-round path must decode the same update and charge
        // the same bytes as the mutating path (only jitter noise, which
        // affects times, comes from a different rng stream)
        let u = update(256);
        let mut direct = channel(Compression::None, true);
        let mut w = wan();
        let d = direct.send_update(&u, 0.5, 9, 2.0, &mut w).unwrap();
        let mut scoped = channel(Compression::None, true);
        let w2 = wan();
        let mut rng = Pcg64::new(7, 1);
        let mut scratch = WanScratch::default();
        let s = scoped
            .send_update_scoped(&u, 0.5, 9, 2.0, &w2, &mut rng, &mut scratch)
            .unwrap();
        assert_eq!(s.update, d.update);
        assert_eq!(s.local_loss, d.local_loss);
        assert_eq!(s.n_samples, d.n_samples);
        assert_eq!(s.weight, d.weight);
        assert_eq!(s.wire_bytes, d.wire_bytes);
        assert_eq!(scoped.payload_bytes, direct.payload_bytes);
    }
}

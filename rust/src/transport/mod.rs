//! Update transport pipeline: serialize → compress → encrypt → WAN.
//!
//! Every worker→leader update and leader→worker broadcast passes through
//! here, so every byte in Table 2's "Communication Overhead" column is a
//! byte this module actually produced (compression output + seal overhead
//! + protocol framing from the netsim).

use anyhow::{Context, Result};

use crate::compress::{CompressedPayload, Compressor, ErrorFeedback};
use crate::crypto::{open, seal, TransportKey};
use crate::model::ParamSet;
use crate::netsim::{Protocol, Wan};
use crate::util::bytes::{f32s_to_le, le_to_f32s};

/// Per-direction transport channel with its compression + crypto state.
pub struct Channel {
    pub src: usize,
    pub dst: usize,
    pub protocol: Protocol,
    pub streams: usize,
    compressor: Compressor,
    error_feedback: Option<ErrorFeedback>,
    /// encryption keys (None = plaintext transport, for the ablation)
    send_key: Option<TransportKey>,
    recv_key: Option<TransportKey>,
    /// cumulative payload bytes (pre-framing, post-compression+seal)
    pub payload_bytes: u64,
}

/// What arrives at the far end, plus the cost of getting it there.
#[derive(Clone, Debug)]
pub struct Delivery {
    /// the decompressed update as the receiver sees it
    pub update: ParamSet,
    /// metadata forwarded alongside
    pub local_loss: f32,
    pub n_samples: usize,
    /// simulated transfer seconds (incl. handshake/stalls)
    pub secs: f64,
    /// bytes on the wire (payload + framing + retransmits)
    pub wire_bytes: u64,
}

impl Channel {
    /// `secret`: shared transport secret (None disables encryption).
    pub fn new(
        src: usize,
        dst: usize,
        protocol: Protocol,
        streams: usize,
        compressor: Compressor,
        error_feedback: bool,
        n_params: usize,
        secret: Option<&[u8]>,
    ) -> Channel {
        let ef = error_feedback.then(|| ErrorFeedback::new(n_params, true));
        let ctx = format!("{src}->{dst}");
        Channel {
            src,
            dst,
            protocol,
            streams,
            compressor,
            error_feedback: ef,
            send_key: secret.map(|s| TransportKey::derive(s, &ctx)),
            recv_key: secret.map(|s| TransportKey::derive(s, &ctx)),
            payload_bytes: 0,
        }
    }

    /// Send an update over the WAN: returns what the receiver decodes.
    ///
    /// The pipeline is real end-to-end: the exact bytes produced by
    /// compression (+ sealing) determine both the netsim cost and what is
    /// decompressed on the far side (so lossy compression affects
    /// convergence, not just byte counts).
    pub fn send_update(
        &mut self,
        update: &ParamSet,
        local_loss: f32,
        n_samples: usize,
        wan: &mut Wan,
    ) -> Result<Delivery> {
        let flat = update.to_flat();
        let payload = match &mut self.error_feedback {
            Some(ef) => ef.compress(&flat, &mut self.compressor)?,
            None => self.compressor.compress(&flat),
        };

        // metadata header: loss (4) + n_samples (8) + leaf count (4)
        let mut plaintext =
            Vec::with_capacity(payload.data.len() + 16);
        plaintext.extend_from_slice(&local_loss.to_le_bytes());
        plaintext.extend_from_slice(&(n_samples as u64).to_le_bytes());
        plaintext.extend_from_slice(&(payload.n as u32).to_le_bytes());
        plaintext.extend_from_slice(&payload.data);

        let (wire_payload, n_bytes) = match &mut self.send_key {
            Some(key) => {
                let sealed = seal(key, &plaintext);
                let n = sealed.byte_len();
                (WirePayload::Sealed(sealed), n)
            }
            None => {
                let n = plaintext.len() as u64;
                (WirePayload::Plain(plaintext.clone()), n)
            }
        };
        self.payload_bytes += n_bytes;

        let stats =
            wan.transfer(self.src, self.dst, n_bytes, self.protocol, self.streams);

        // receiver side: decrypt, parse, decompress
        let recv_plain = match (&wire_payload, &self.recv_key) {
            (WirePayload::Sealed(s), Some(key)) => {
                open(key, s).context("transport decrypt")?
            }
            (WirePayload::Plain(p), _) => p.clone(),
            (WirePayload::Sealed(_), None) => unreachable!(),
        };
        let (meta_loss, meta_n, decoded) =
            Self::parse_frame(&recv_plain, payload.scheme)?;

        let update = ParamSet::from_flat(&decoded, update)
            .context("decoded update has wrong size")?;
        Ok(Delivery {
            update,
            local_loss: meta_loss,
            n_samples: meta_n,
            secs: stats.time_s,
            wire_bytes: stats.wire_bytes,
        })
    }

    fn parse_frame(
        plain: &[u8],
        scheme: crate::compress::Compression,
    ) -> Result<(f32, usize, Vec<f32>)> {
        anyhow::ensure!(plain.len() >= 16, "frame too short");
        let loss = f32::from_le_bytes(plain[0..4].try_into().unwrap());
        let n_samples =
            u64::from_le_bytes(plain[4..12].try_into().unwrap()) as usize;
        let n_elems =
            u32::from_le_bytes(plain[12..16].try_into().unwrap()) as usize;
        let payload = CompressedPayload {
            scheme,
            n: n_elems,
            data: plain[16..].to_vec(),
        };
        let decoded = Compressor::decompress(&payload)?;
        Ok((loss, n_samples, decoded))
    }

    /// Broadcast raw params (dense f32, optionally sealed) to a worker.
    /// Returns (secs, wire_bytes).
    pub fn send_params(
        &mut self,
        params: &ParamSet,
        wan: &mut Wan,
    ) -> Result<(f64, u64)> {
        let plaintext = f32s_to_le(&params.to_flat());
        let n_bytes = match &mut self.send_key {
            Some(key) => {
                let sealed = seal(key, &plaintext);
                // receiver-side verification (keeps crypto honest)
                let back = open(self.recv_key.as_ref().unwrap(), &sealed)?;
                anyhow::ensure!(
                    le_to_f32s(&back).is_some(),
                    "broadcast decode failed"
                );
                sealed.byte_len()
            }
            None => plaintext.len() as u64,
        };
        self.payload_bytes += n_bytes;
        let stats =
            wan.transfer(self.src, self.dst, n_bytes, self.protocol, self.streams);
        Ok((stats.time_s, stats.wire_bytes))
    }
}

enum WirePayload {
    Plain(Vec<u8>),
    Sealed(crate::crypto::SealedPayload),
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::Compression;
    use crate::netsim::Link;

    fn wan() -> Wan {
        Wan::uniform(3, Link::new(1e9, 0.02), 7)
    }

    fn update(n: usize) -> ParamSet {
        ParamSet {
            leaves: vec![(0..n).map(|i| (i as f32 * 0.01).sin()).collect()],
        }
    }

    fn channel(compression: Compression, encrypted: bool) -> Channel {
        Channel::new(
            1,
            0,
            Protocol::Grpc,
            8,
            Compressor::new(compression, 3),
            matches!(compression, Compression::TopK { .. }),
            256,
            encrypted.then_some(b"secret".as_slice()),
        )
    }

    #[test]
    fn dense_encrypted_roundtrip() {
        let mut ch = channel(Compression::None, true);
        let mut w = wan();
        let u = update(256);
        let d = ch.send_update(&u, 1.25, 999, &mut w).unwrap();
        assert_eq!(d.update, u); // lossless end-to-end
        assert_eq!(d.local_loss, 1.25);
        assert_eq!(d.n_samples, 999);
        assert!(d.secs > 0.0);
        // sealed: 256*4 + 16 header + 48 seal
        assert_eq!(ch.payload_bytes, 256 * 4 + 16 + 48);
    }

    #[test]
    fn plaintext_skips_seal_overhead() {
        let mut enc = channel(Compression::None, true);
        let mut plain = channel(Compression::None, false);
        let mut w = wan();
        let u = update(256);
        enc.send_update(&u, 0.0, 1, &mut w).unwrap();
        plain.send_update(&u, 0.0, 1, &mut w).unwrap();
        assert_eq!(enc.payload_bytes - plain.payload_bytes, 48);
    }

    #[test]
    fn topk_shrinks_wire_bytes_and_loses_info() {
        let mut dense = channel(Compression::None, true);
        let mut sparse = channel(Compression::TopK { ratio: 0.05 }, true);
        let mut w = wan();
        let u = update(256);
        let dd = dense.send_update(&u, 0.0, 1, &mut w).unwrap();
        let ds = sparse.send_update(&u, 0.0, 1, &mut w).unwrap();
        assert!(sparse.payload_bytes < dense.payload_bytes / 5);
        assert!(ds.wire_bytes < dd.wire_bytes / 5);
        // lossy: only some coords survive
        let nonzero = ds.update.leaves[0].iter().filter(|&&x| x != 0.0).count();
        assert!(nonzero <= 13);
        assert_eq!(dd.update, u);
    }

    #[test]
    fn broadcast_counts_bytes() {
        let mut ch = channel(Compression::None, true);
        let mut w = wan();
        let (secs, wire) = ch.send_params(&update(256), &mut w).unwrap();
        assert!(secs > 0.0);
        assert!(wire >= 256 * 4);
    }

    #[test]
    fn wire_bytes_exceed_payload_bytes() {
        // framing overhead must show up in the ledger
        let mut ch = channel(Compression::None, false);
        let mut w = wan();
        let d = ch.send_update(&update(1024), 0.0, 1, &mut w).unwrap();
        assert!(d.wire_bytes > ch.payload_bytes);
    }
}

//! [`ParamSet`] — the flat-leaf parameter representation.
//!
//! Every aggregation algorithm (formulas 1–4 of the paper), the server
//! optimizer, compression and DP all operate on this type. Leaves are kept
//! as separate `Vec<f32>`s in manifest order so they can be handed to the
//! PJRT executable without re-slicing.
//!
//! The linear-algebra kernels (`axpy`/`axpy_many`/`scale`/`sub`/`l2_norm`/
//! `to_flat`) are block-parallel over [`par::BLOCK`]-element chunks; block
//! boundaries are fixed, so results are bit-identical for any thread count
//! (EXPERIMENTS.md §Perf).

use crate::model::manifest::{InitKind, Manifest};
use crate::util::par;
use crate::util::rng::Pcg64;

/// Flat model parameters (or gradients / update deltas — same layout).
#[derive(Clone, Debug, PartialEq, Default)]
pub struct ParamSet {
    pub leaves: Vec<Vec<f32>>,
}

impl ParamSet {
    /// All-zero set with the manifest's shapes.
    pub fn zeros_like(manifest: &Manifest) -> ParamSet {
        ParamSet {
            leaves: manifest.params.iter().map(|p| vec![0.0; p.numel()]).collect(),
        }
    }

    /// Initialize per the manifest init schemes (deterministic in `seed`).
    ///
    /// This mirrors python's `model.init_params` in distribution (normal
    /// with the spec's std; zeros; ones) though not bit-for-bit — training
    /// starts from an equivalent, reproducible init.
    pub fn init(manifest: &Manifest, seed: u64) -> ParamSet {
        let mut rng = Pcg64::new(seed, 0x9a7a);
        let leaves = manifest
            .params
            .iter()
            .map(|p| match p.init {
                InitKind::Zeros => vec![0.0; p.numel()],
                InitKind::Ones => vec![1.0; p.numel()],
                InitKind::Normal => (0..p.numel())
                    .map(|_| rng.normal_ms(0.0, p.std) as f32)
                    .collect(),
            })
            .collect();
        ParamSet { leaves }
    }

    /// Total number of scalar parameters.
    pub fn numel(&self) -> usize {
        self.leaves.iter().map(|l| l.len()).sum()
    }

    /// Number of leaves.
    pub fn n_leaves(&self) -> usize {
        self.leaves.len()
    }

    /// Serialized payload size in bytes (uncompressed f32).
    pub fn byte_size(&self) -> u64 {
        (self.numel() * 4) as u64
    }

    /// self += alpha * other (axpy across all leaves, block-parallel).
    pub fn axpy(&mut self, alpha: f32, other: &ParamSet) {
        self.axpy_many(&[(alpha, other)]);
    }

    /// self += Σ_k alpha_k · other_k in one pass: each destination block is
    /// read and written once however many updates are applied (the
    /// aggregation inner loop). Per element the terms are added in order,
    /// so the result is bit-identical to the equivalent sequence of
    /// [`ParamSet::axpy`] calls.
    pub fn axpy_many(&mut self, terms: &[(f32, &ParamSet)]) {
        for (_, o) in terms {
            assert_eq!(self.leaves.len(), o.leaves.len(), "leaf count mismatch");
        }
        let total = self.numel() * terms.len().max(1);
        if total <= par::PAR_THRESHOLD || par::current_threads() == 1 {
            // allocation-free serial path (the per-training-step case);
            // per element the terms apply in the same order as the block
            // path, so both are bit-identical
            for (li, a) in self.leaves.iter_mut().enumerate() {
                for &(alpha, o) in terms {
                    let src = &o.leaves[li];
                    assert_eq!(a.len(), src.len(), "leaf shape mismatch");
                    for (x, y) in a.iter_mut().zip(src) {
                        *x += alpha * y;
                    }
                }
            }
            return;
        }
        let mut items: Vec<(usize, usize, &mut [f32])> = Vec::new();
        for (li, a) in self.leaves.iter_mut().enumerate() {
            for (_, o) in terms {
                assert_eq!(a.len(), o.leaves[li].len(), "leaf shape mismatch");
            }
            for (bi, c) in a.chunks_mut(par::BLOCK).enumerate() {
                items.push((li, bi * par::BLOCK, c));
            }
        }
        par::run_items_auto(total, items, |(li, off, chunk)| {
            for &(alpha, o) in terms {
                let src = &o.leaves[li][off..off + chunk.len()];
                for (x, y) in chunk.iter_mut().zip(src) {
                    *x += alpha * y;
                }
            }
        });
    }

    /// self *= alpha (block-parallel).
    pub fn scale(&mut self, alpha: f32) {
        let total = self.numel();
        let mut items: Vec<&mut [f32]> = Vec::new();
        for l in &mut self.leaves {
            for c in l.chunks_mut(par::BLOCK) {
                items.push(c);
            }
        }
        par::run_items_auto(total, items, |chunk| {
            for x in chunk.iter_mut() {
                *x *= alpha;
            }
        });
    }

    /// self = 0.
    pub fn zero(&mut self) {
        let total = self.numel();
        let mut items: Vec<&mut [f32]> = Vec::new();
        for l in &mut self.leaves {
            for c in l.chunks_mut(par::BLOCK) {
                items.push(c);
            }
        }
        par::run_items_auto(total, items, |chunk| chunk.fill(0.0));
    }

    /// Element-wise difference: self - other (the "update delta" a worker
    /// sends in parameter-aggregation modes). Block-parallel.
    pub fn sub(&self, other: &ParamSet) -> ParamSet {
        assert_eq!(self.leaves.len(), other.leaves.len());
        let mut out = ParamSet {
            leaves: self.leaves.iter().map(|l| vec![0.0; l.len()]).collect(),
        };
        let total = self.numel();
        let mut items: Vec<(&mut [f32], &[f32], &[f32])> = Vec::new();
        for ((o, a), b) in
            out.leaves.iter_mut().zip(&self.leaves).zip(&other.leaves)
        {
            assert_eq!(a.len(), b.len());
            for ((co, ca), cb) in o
                .chunks_mut(par::BLOCK)
                .zip(a.chunks(par::BLOCK))
                .zip(b.chunks(par::BLOCK))
            {
                items.push((co, ca, cb));
            }
        }
        par::run_items_auto(total, items, |(co, ca, cb)| {
            for ((o, x), y) in co.iter_mut().zip(ca).zip(cb) {
                *o = x - y;
            }
        });
        out
    }

    /// Global L2 norm over all leaves.
    ///
    /// Summation is blocked: per-[`par::BLOCK`] partial sums in f64,
    /// combined in (leaf, block) order — deterministic for any thread
    /// count.
    pub fn l2_norm(&self) -> f64 {
        let total = self.numel();
        let nblocks: usize = self
            .leaves
            .iter()
            .map(|l| l.len().div_ceil(par::BLOCK))
            .sum();
        let mut partials = vec![0.0f64; nblocks];
        let items: Vec<(&[f32], &mut f64)> = self
            .leaves
            .iter()
            .flat_map(|l| l.chunks(par::BLOCK))
            .zip(partials.iter_mut())
            .collect();
        par::run_items_auto(total, items, |(c, p)| {
            *p = c.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>();
        });
        partials.iter().sum::<f64>().sqrt()
    }

    /// Flatten to one contiguous vector (transport payload layout).
    pub fn to_flat(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.numel()];
        self.write_flat(&mut out);
        out
    }

    /// Flatten into a caller-owned buffer (the transport's round-persistent
    /// buffer): parallel copy, zero allocation.
    pub fn write_flat(&self, out: &mut [f32]) {
        assert_eq!(out.len(), self.numel(), "flat buffer size mismatch");
        let total = out.len();
        let mut items: Vec<(&mut [f32], &[f32])> = Vec::new();
        let mut rest = out;
        for l in &self.leaves {
            let (head, tail) = std::mem::take(&mut rest).split_at_mut(l.len());
            for (d, s) in head.chunks_mut(par::BLOCK).zip(l.chunks(par::BLOCK)) {
                items.push((d, s));
            }
            rest = tail;
        }
        par::run_items_auto(total, items, |(d, s)| d.copy_from_slice(s));
    }

    /// Structure-preserving copy that reuses this set's allocations when
    /// the shapes already match (the worker's per-round scratch path).
    pub fn copy_from(&mut self, other: &ParamSet) {
        let same_shape = self.leaves.len() == other.leaves.len()
            && self
                .leaves
                .iter()
                .zip(&other.leaves)
                .all(|(a, b)| a.len() == b.len());
        if !same_shape {
            self.leaves = other.leaves.clone();
            return;
        }
        let total = self.numel();
        let mut items: Vec<(&mut [f32], &[f32])> = Vec::new();
        for (a, b) in self.leaves.iter_mut().zip(&other.leaves) {
            for (d, s) in a.chunks_mut(par::BLOCK).zip(b.chunks(par::BLOCK)) {
                items.push((d, s));
            }
        }
        par::run_items_auto(total, items, |(d, s)| d.copy_from_slice(s));
    }

    /// Rebuild from a flat vector given the leaf sizes of `like`.
    pub fn from_flat(flat: &[f32], like: &ParamSet) -> Option<ParamSet> {
        if flat.len() != like.numel() {
            return None;
        }
        let mut leaves = Vec::with_capacity(like.leaves.len());
        let mut off = 0;
        for l in &like.leaves {
            leaves.push(flat[off..off + l.len()].to_vec());
            off += l.len();
        }
        Some(ParamSet { leaves })
    }

    /// Max absolute element (used in tests / divergence checks).
    pub fn max_abs(&self) -> f32 {
        self.leaves
            .iter()
            .flat_map(|l| l.iter())
            .fold(0.0f32, |m, &x| m.max(x.abs()))
    }

    /// True if any element is NaN/inf (training blow-up detector).
    pub fn has_non_finite(&self) -> bool {
        self.leaves.iter().any(|l| l.iter().any(|x| !x.is_finite()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::manifest::Manifest;
    use std::path::Path;

    fn manifest() -> Manifest {
        Manifest::parse(
            r#"{
 "preset": "t",
 "model": {"vocab_size": 4, "d_model": 2, "n_heads": 1, "n_layers": 1,
           "d_ff": 4, "seq_len": 4, "batch_size": 1, "n_params": 14},
 "params": [
   {"name": "w", "shape": [4, 2], "init": "normal", "std": 0.5},
   {"name": "s", "shape": [4], "init": "ones", "std": 0.0},
   {"name": "b", "shape": [2], "init": "zeros", "std": 0.0}
 ],
 "io": {},
 "artifacts": {"train": "t.hlo.txt", "eval": "e.hlo.txt"}
}"#,
            Path::new("/x"),
        )
        .unwrap()
    }

    #[test]
    fn init_respects_schemes() {
        let m = manifest();
        let p = ParamSet::init(&m, 1);
        assert_eq!(p.n_leaves(), 3);
        assert_eq!(p.numel(), 14);
        assert!(p.leaves[0].iter().any(|&x| x != 0.0));
        assert!(p.leaves[1].iter().all(|&x| x == 1.0));
        assert!(p.leaves[2].iter().all(|&x| x == 0.0));
        // deterministic
        assert_eq!(p, ParamSet::init(&m, 1));
        assert_ne!(p, ParamSet::init(&m, 2));
    }

    #[test]
    fn axpy_scale_sub() {
        let m = manifest();
        let mut a = ParamSet::init(&m, 1);
        let b = ParamSet::init(&m, 2);
        let orig = a.clone();
        a.axpy(2.0, &b);
        let d = a.sub(&orig);
        for (dl, bl) in d.leaves.iter().zip(&b.leaves) {
            for (x, y) in dl.iter().zip(bl) {
                assert!((x - 2.0 * y).abs() < 1e-5);
            }
        }
        a.scale(0.0);
        assert_eq!(a.l2_norm(), 0.0);
    }

    #[test]
    fn flat_roundtrip() {
        let m = manifest();
        let p = ParamSet::init(&m, 3);
        let flat = p.to_flat();
        assert_eq!(flat.len(), 14);
        let q = ParamSet::from_flat(&flat, &p).unwrap();
        assert_eq!(p, q);
        assert!(ParamSet::from_flat(&flat[1..], &p).is_none());
    }

    #[test]
    fn norm_and_finite() {
        let m = manifest();
        let mut p = ParamSet::zeros_like(&m);
        assert_eq!(p.l2_norm(), 0.0);
        assert!(!p.has_non_finite());
        p.leaves[0][0] = f32::NAN;
        assert!(p.has_non_finite());
    }

    #[test]
    fn byte_size() {
        let m = manifest();
        assert_eq!(ParamSet::zeros_like(&m).byte_size(), 14 * 4);
    }

    #[test]
    fn axpy_many_matches_sequential_axpy() {
        let m = manifest();
        let u1 = ParamSet::init(&m, 4);
        let u2 = ParamSet::init(&m, 5);
        let mut seq = ParamSet::init(&m, 6);
        let mut fused = seq.clone();
        seq.axpy(0.25, &u1);
        seq.axpy(-1.5, &u2);
        fused.axpy_many(&[(0.25, &u1), (-1.5, &u2)]);
        assert_eq!(seq, fused); // bit-identical, not just close
    }

    #[test]
    fn write_flat_and_copy_from() {
        let m = manifest();
        let p = ParamSet::init(&m, 7);
        let mut buf = vec![9.0f32; p.numel()];
        p.write_flat(&mut buf);
        assert_eq!(buf, p.to_flat());

        // matching shapes: reuses allocations; mismatched: reshapes
        let mut q = ParamSet::zeros_like(&m);
        q.copy_from(&p);
        assert_eq!(q, p);
        let mut r = ParamSet::default();
        r.copy_from(&p);
        assert_eq!(r, p);
    }

    #[test]
    fn empty_and_degenerate_shapes() {
        let empty = ParamSet::default();
        assert_eq!(empty.numel(), 0);
        assert_eq!(empty.l2_norm(), 0.0);
        assert_eq!(empty.to_flat(), Vec::<f32>::new());

        let mut odd = ParamSet { leaves: vec![vec![], vec![2.0], vec![]] };
        let one = odd.clone();
        odd.axpy(2.0, &one);
        assert_eq!(odd.leaves[1][0], 6.0);
        assert_eq!(one.sub(&one).l2_norm(), 0.0);
    }
}

//! [`ParamSet`] — the flat-leaf parameter representation.
//!
//! Every aggregation algorithm (formulas 1–4 of the paper), the server
//! optimizer, compression and DP all operate on this type. Leaves are kept
//! as separate `Vec<f32>`s in manifest order so they can be handed to the
//! PJRT executable without re-slicing.

use crate::model::manifest::{InitKind, Manifest};
use crate::util::rng::Pcg64;

/// Flat model parameters (or gradients / update deltas — same layout).
#[derive(Clone, Debug, PartialEq)]
pub struct ParamSet {
    pub leaves: Vec<Vec<f32>>,
}

impl ParamSet {
    /// All-zero set with the manifest's shapes.
    pub fn zeros_like(manifest: &Manifest) -> ParamSet {
        ParamSet {
            leaves: manifest.params.iter().map(|p| vec![0.0; p.numel()]).collect(),
        }
    }

    /// Initialize per the manifest init schemes (deterministic in `seed`).
    ///
    /// This mirrors python's `model.init_params` in distribution (normal
    /// with the spec's std; zeros; ones) though not bit-for-bit — training
    /// starts from an equivalent, reproducible init.
    pub fn init(manifest: &Manifest, seed: u64) -> ParamSet {
        let mut rng = Pcg64::new(seed, 0x9a7a);
        let leaves = manifest
            .params
            .iter()
            .map(|p| match p.init {
                InitKind::Zeros => vec![0.0; p.numel()],
                InitKind::Ones => vec![1.0; p.numel()],
                InitKind::Normal => (0..p.numel())
                    .map(|_| rng.normal_ms(0.0, p.std) as f32)
                    .collect(),
            })
            .collect();
        ParamSet { leaves }
    }

    /// Total number of scalar parameters.
    pub fn numel(&self) -> usize {
        self.leaves.iter().map(|l| l.len()).sum()
    }

    /// Number of leaves.
    pub fn n_leaves(&self) -> usize {
        self.leaves.len()
    }

    /// Serialized payload size in bytes (uncompressed f32).
    pub fn byte_size(&self) -> u64 {
        (self.numel() * 4) as u64
    }

    /// self += alpha * other (axpy across all leaves).
    pub fn axpy(&mut self, alpha: f32, other: &ParamSet) {
        assert_eq!(self.leaves.len(), other.leaves.len(), "leaf count mismatch");
        for (a, b) in self.leaves.iter_mut().zip(&other.leaves) {
            assert_eq!(a.len(), b.len(), "leaf shape mismatch");
            for (x, y) in a.iter_mut().zip(b) {
                *x += alpha * y;
            }
        }
    }

    /// self *= alpha.
    pub fn scale(&mut self, alpha: f32) {
        for l in &mut self.leaves {
            for x in l.iter_mut() {
                *x *= alpha;
            }
        }
    }

    /// self = 0.
    pub fn zero(&mut self) {
        for l in &mut self.leaves {
            l.fill(0.0);
        }
    }

    /// Element-wise difference: self - other (the "update delta" a worker
    /// sends in parameter-aggregation modes).
    pub fn sub(&self, other: &ParamSet) -> ParamSet {
        assert_eq!(self.leaves.len(), other.leaves.len());
        ParamSet {
            leaves: self
                .leaves
                .iter()
                .zip(&other.leaves)
                .map(|(a, b)| {
                    assert_eq!(a.len(), b.len());
                    a.iter().zip(b).map(|(x, y)| x - y).collect()
                })
                .collect(),
        }
    }

    /// Global L2 norm over all leaves.
    pub fn l2_norm(&self) -> f64 {
        self.leaves
            .iter()
            .flat_map(|l| l.iter())
            .map(|&x| (x as f64) * (x as f64))
            .sum::<f64>()
            .sqrt()
    }

    /// Flatten to one contiguous vector (transport payload layout).
    pub fn to_flat(&self) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.numel());
        for l in &self.leaves {
            out.extend_from_slice(l);
        }
        out
    }

    /// Rebuild from a flat vector given the leaf sizes of `like`.
    pub fn from_flat(flat: &[f32], like: &ParamSet) -> Option<ParamSet> {
        if flat.len() != like.numel() {
            return None;
        }
        let mut leaves = Vec::with_capacity(like.leaves.len());
        let mut off = 0;
        for l in &like.leaves {
            leaves.push(flat[off..off + l.len()].to_vec());
            off += l.len();
        }
        Some(ParamSet { leaves })
    }

    /// Max absolute element (used in tests / divergence checks).
    pub fn max_abs(&self) -> f32 {
        self.leaves
            .iter()
            .flat_map(|l| l.iter())
            .fold(0.0f32, |m, &x| m.max(x.abs()))
    }

    /// True if any element is NaN/inf (training blow-up detector).
    pub fn has_non_finite(&self) -> bool {
        self.leaves.iter().any(|l| l.iter().any(|x| !x.is_finite()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::manifest::Manifest;
    use std::path::Path;

    fn manifest() -> Manifest {
        Manifest::parse(
            r#"{
 "preset": "t",
 "model": {"vocab_size": 4, "d_model": 2, "n_heads": 1, "n_layers": 1,
           "d_ff": 4, "seq_len": 4, "batch_size": 1, "n_params": 14},
 "params": [
   {"name": "w", "shape": [4, 2], "init": "normal", "std": 0.5},
   {"name": "s", "shape": [4], "init": "ones", "std": 0.0},
   {"name": "b", "shape": [2], "init": "zeros", "std": 0.0}
 ],
 "io": {},
 "artifacts": {"train": "t.hlo.txt", "eval": "e.hlo.txt"}
}"#,
            Path::new("/x"),
        )
        .unwrap()
    }

    #[test]
    fn init_respects_schemes() {
        let m = manifest();
        let p = ParamSet::init(&m, 1);
        assert_eq!(p.n_leaves(), 3);
        assert_eq!(p.numel(), 14);
        assert!(p.leaves[0].iter().any(|&x| x != 0.0));
        assert!(p.leaves[1].iter().all(|&x| x == 1.0));
        assert!(p.leaves[2].iter().all(|&x| x == 0.0));
        // deterministic
        assert_eq!(p, ParamSet::init(&m, 1));
        assert_ne!(p, ParamSet::init(&m, 2));
    }

    #[test]
    fn axpy_scale_sub() {
        let m = manifest();
        let mut a = ParamSet::init(&m, 1);
        let b = ParamSet::init(&m, 2);
        let orig = a.clone();
        a.axpy(2.0, &b);
        let d = a.sub(&orig);
        for (dl, bl) in d.leaves.iter().zip(&b.leaves) {
            for (x, y) in dl.iter().zip(bl) {
                assert!((x - 2.0 * y).abs() < 1e-5);
            }
        }
        a.scale(0.0);
        assert_eq!(a.l2_norm(), 0.0);
    }

    #[test]
    fn flat_roundtrip() {
        let m = manifest();
        let p = ParamSet::init(&m, 3);
        let flat = p.to_flat();
        assert_eq!(flat.len(), 14);
        let q = ParamSet::from_flat(&flat, &p).unwrap();
        assert_eq!(p, q);
        assert!(ParamSet::from_flat(&flat[1..], &p).is_none());
    }

    #[test]
    fn norm_and_finite() {
        let m = manifest();
        let mut p = ParamSet::zeros_like(&m);
        assert_eq!(p.l2_norm(), 0.0);
        assert!(!p.has_non_finite());
        p.leaves[0][0] = f32::NAN;
        assert!(p.has_non_finite());
    }

    #[test]
    fn byte_size() {
        let m = manifest();
        assert_eq!(ParamSet::zeros_like(&m).byte_size(), 14 * 4);
    }
}

//! Parsing of `artifacts/manifest_<preset>.json`.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// Parameter initialization scheme (mirrors python `ParamSpec.init`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum InitKind {
    Normal,
    Zeros,
    Ones,
}

/// One parameter leaf: name, shape, init.
#[derive(Clone, Debug)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub init: InitKind,
    pub std: f64,
}

impl ParamSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Transformer dimensions (informational; the HLO fixes them anyway).
#[derive(Clone, Debug)]
pub struct ModelDims {
    pub vocab_size: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub n_layers: usize,
    pub d_ff: usize,
    pub seq_len: usize,
    pub batch_size: usize,
    pub n_params: usize,
}

/// Parsed AOT manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub preset: String,
    pub model: ModelDims,
    pub params: Vec<ParamSpec>,
    /// path of the train HLO artifact (absolute, resolved next to manifest)
    pub train_hlo: PathBuf,
    /// path of the eval HLO artifact
    pub eval_hlo: PathBuf,
}

impl Manifest {
    /// Load `manifest_<preset>.json` from an artifacts directory.
    pub fn load(artifacts_dir: &Path, preset: &str) -> Result<Manifest> {
        let path = artifacts_dir.join(format!("manifest_{preset}.json"));
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} (run `make artifacts`?)"))?;
        Self::parse(&text, artifacts_dir)
            .with_context(|| format!("parsing {path:?}"))
    }

    /// Parse manifest JSON; artifact paths resolve relative to `dir`.
    pub fn parse(text: &str, dir: &Path) -> Result<Manifest> {
        let v = Json::parse(text)?;
        let model = v.req("model")?;
        let dims = ModelDims {
            vocab_size: model.req_usize("vocab_size")?,
            d_model: model.req_usize("d_model")?,
            n_heads: model.req_usize("n_heads")?,
            n_layers: model.req_usize("n_layers")?,
            d_ff: model.req_usize("d_ff")?,
            seq_len: model.req_usize("seq_len")?,
            batch_size: model.req_usize("batch_size")?,
            n_params: model.req_usize("n_params")?,
        };

        let mut params = Vec::new();
        for p in v.req("params")?.as_arr().context("params not an array")? {
            let init = match p.req_str("init")? {
                "normal" => InitKind::Normal,
                "zeros" => InitKind::Zeros,
                "ones" => InitKind::Ones,
                other => bail!("unknown init kind {other:?}"),
            };
            let shape = p
                .req("shape")?
                .as_arr()
                .context("shape not an array")?
                .iter()
                .map(|d| d.as_usize().context("bad dim"))
                .collect::<Result<Vec<_>>>()?;
            params.push(ParamSpec {
                name: p.req_str("name")?.to_string(),
                shape,
                init,
                std: p.opt_f64("std", 0.0),
            });
        }
        if params.is_empty() {
            bail!("manifest has no params");
        }
        let total: usize = params.iter().map(|p| p.numel()).sum();
        if total != dims.n_params {
            bail!(
                "manifest n_params {} != sum of leaf sizes {}",
                dims.n_params,
                total
            );
        }

        let artifacts = v.req("artifacts")?;
        Ok(Manifest {
            preset: v.req_str("preset")?.to_string(),
            model: dims,
            params,
            train_hlo: dir.join(artifacts.req_str("train")?),
            eval_hlo: dir.join(artifacts.req_str("eval")?),
        })
    }

    /// Total parameter count.
    pub fn n_params(&self) -> usize {
        self.params.iter().map(|p| p.numel()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_manifest() -> String {
        r#"{
 "preset": "test",
 "model": {"vocab_size": 8, "d_model": 4, "n_heads": 1, "n_layers": 1,
           "d_ff": 8, "seq_len": 4, "batch_size": 2, "n_params": 36},
 "params": [
   {"name": "tok_emb", "shape": [8, 4], "init": "normal", "std": 0.02},
   {"name": "ln.scale", "shape": [4], "init": "ones", "std": 0.0}
 ],
 "io": {},
 "artifacts": {"train": "train_test.hlo.txt", "eval": "eval_test.hlo.txt"}
}"#
        .to_string()
    }

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(&sample_manifest(), Path::new("/a")).unwrap();
        assert_eq!(m.preset, "test");
        assert_eq!(m.params.len(), 2);
        assert_eq!(m.params[0].numel(), 32);
        assert_eq!(m.params[1].init, InitKind::Ones);
        assert_eq!(m.n_params(), 36);
        assert_eq!(m.train_hlo, Path::new("/a/train_test.hlo.txt"));
    }

    #[test]
    fn rejects_param_count_mismatch() {
        let bad = sample_manifest().replace("\"n_params\": 36", "\"n_params\": 35");
        assert!(Manifest::parse(&bad, Path::new("/a")).is_err());
    }

    #[test]
    fn rejects_unknown_init() {
        let bad = sample_manifest().replace("\"ones\"", "\"foo\"");
        assert!(Manifest::parse(&bad, Path::new("/a")).is_err());
    }
}

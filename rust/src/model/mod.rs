//! Model parameter handling on the rust side.
//!
//! The AOT manifest (emitted by `python/compile/aot.py`) is the single
//! source of truth for parameter order, shapes and init; this module
//! parses it and provides [`ParamSet`] — the flat-leaf representation all
//! aggregation algorithms operate on.

mod manifest;
mod params;

pub use manifest::{InitKind, Manifest, ModelDims, ParamSpec};
pub use params::ParamSet;

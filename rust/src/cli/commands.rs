//! CLI subcommand implementations.

use anyhow::{bail, Context, Result};
use std::path::PathBuf;

use crate::cli::args::Args;
use crate::cluster::ClusterSpec;
use crate::config::{preset, preset_names, ExperimentConfig};
use crate::coordinator::Coordinator;
use crate::data::SyntheticCorpus;
use crate::metrics::RunResult;
use crate::model::{Manifest, ParamSet};
use crate::partition::PartitionPlanner;
use crate::report;
use crate::runtime::{ComputeBackend, MockRuntime, StepRuntime};
use crate::util::bytes::{human_bytes, human_duration};

const FLAGS: [&str; 6] =
    ["mock", "no-encrypt", "curve", "hierarchical", "par-rounds", "spot"];

const USAGE: &str = "\
crossfed — cross-cloud federated LLM training (Yang et al. 2024 reproduction)

USAGE:
  crossfed train [--preset NAME | --config FILE] [--agg A] [--rounds N]
                 [--protocol P] [--compression C] [--partition S]
                 [--lossless none|xor|varint|auto]
                 [--artifacts DIR] [--model-preset M] [--seed N]
                 [--save-checkpoint PATH] [--resume PATH]
                 [--wal DIR] [--target-cost USD]
                 [--nodes-per-cloud N] [--hierarchical] [--spot]
                 [--placement auto|fixed:N] [--price-book FILE]
                 [--fault SPEC[;SPEC...]] [--mock] [--curve]
                 [--par-rounds] [--history-every N] [--history-csv FILE]
  crossfed sweep --presets a,b,c [--artifacts DIR] [--mock]
  crossfed sweep --preset NAME --placements p1,p2 --codecs c1,c2 [--mock]
  crossfed serve [--preset NAME] [--route latency,cost,blended:W]
                 [--clouds N] [--users N] [--hours H] [--seed N]
                 [--refresh-secs S] [--max-batch N] [--model-params N]
                 [--from-checkpoint PATH] [--price-book FILE]
  crossfed inspect [--preset NAME]
  crossfed partition-plan [--strategy S] [--platforms N]
  crossfed list-presets

Artifacts default to ./artifacts (built by `make artifacts`). --mock swaps
the PJRT backend for the quadratic mock (no artifacts needed).
--nodes-per-cloud puts N AZ-level worker nodes inside each of the 3 paper
clouds; --hierarchical reduces each cloud at its gateway so only one
partial aggregate per cloud crosses the inter-region WAN.
--placement picks the leader cloud: fixed:N pins it (default fixed:0),
auto scores every cloud's expected egress dollars against the price book
and takes the cheapest. --price-book FILE loads a JSON price book
(per-cloud $/node-hour + tiered $/GB egress per link class; see
EXPERIMENTS.md §Cost); every run prints its dollar bill either way.
--fault injects deterministic failures at round boundaries (replaces the
preset's fault plan); `;`-separated specs, e.g.
  --fault \"gateway-down:cloud=1,at=round3;node-slowdown:node=2,at=5,factor=2\"
Kinds: gateway-down (cloud, at), restore (cloud, at — the egress comes
back and the gateway role fails back), link-degrade (src, dst, at,
factor), node-slowdown (node, at, factor), coordinator-crash (at — the
leader process dies at the start of round `at`; requires --wal),
worker-leave (node, at — the member drops out of the roster at the
round boundary; secure aggregation re-keys over the survivors) and
worker-join (node, at — a departed member rejoins and the partition
plan regenerates). gateway-down needs a standby member: run with
--nodes-per-cloud >= 2; so does worker-leave on a gateway node.
--agg async with --hierarchical selects the buffered asynchronous
hierarchy: each gateway mixes member updates into a buffer as they
arrive (rate alpha/(1+staleness)) and ships it when every active member
contributed once; the leader applies cloud buffers without any
cross-cloud barrier. --spot bills every non-gateway node at its cloud's
preemptible rate (see the price book's spot_rate). Preset
paper-hier-async-spot bundles buffered async, spot billing and a
scripted preemption churn — the spot-market scenario.
Preset paper-hier-faulty bundles a mid-run gateway kill with the
hierarchical setup; paper-hier-cost bundles auto placement with the
paper price book.
--wal DIR appends a CRC-checked, fsynced write-ahead record of the full
coordinator state at every round boundary; after a crash (injected or
real), `--resume DIR` replays it and continues bit-identically — the
resumed run's losses, wire bytes and dollar bill match an uninterrupted
run exactly. --resume with a file path restores a --save-checkpoint
snapshot instead (coarser: params + RNG streams only).
--target-cost stops the run at the first round boundary whose cumulative
bill reaches the budget (the cost analogue of a loss target).
--par-rounds runs each cloud's intra-round traffic on its own thread
(hierarchical only; deterministic at any thread count via per-cloud RNG
streams — see CROSSFED_THREADS). --history-every N keeps every Nth round
record in memory; --history-csv FILE streams every round to a CSV as it
completes, so long runs don't need the full in-memory history.
`sweep --placements ... --codecs ...` runs one preset over the full
placement × codec grid and prints the cost table plus a delta table
against the first combination (the cost what-if ablation).
`serve` deploys the trained model: one replica per cloud, a seeded
diurnal request population (millions of users), and a routing policy
per --route entry (comma-separated; each runs as its own sweep leg).
latency stays near the user, cost ships requests to the cheapest
cloud (same scoring as training's auto placement), blended:W weighs
the two. --from-checkpoint serves the actual trained weights (param
count sets service times, size sets refresh payloads); --refresh-secs
republishes on that period, closing the train->deploy loop with a
staleness column. Reports p50/p99 latency, queue depths and
$/million-requests, billed by the same price book as training.";

/// Entry point used by main.rs. Returns process exit code.
pub fn run_cli(raw: &[String]) -> Result<i32> {
    let args = Args::parse(raw, &FLAGS)?;
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "train" => cmd_train(&args),
        "sweep" => cmd_sweep(&args),
        "serve" => cmd_serve(&args),
        "inspect" => cmd_inspect(&args),
        "partition-plan" => cmd_partition_plan(&args),
        "list-presets" => {
            for p in preset_names() {
                println!("{p}");
            }
            Ok(0)
        }
        "help" | "--help" | "-h" => {
            println!("{USAGE}");
            Ok(0)
        }
        other => {
            eprintln!("unknown command {other:?}\n\n{USAGE}");
            Ok(2)
        }
    }
}

/// Build the config from --preset/--config + overrides.
fn build_config(args: &Args) -> Result<ExperimentConfig> {
    let mut cfg = if let Some(path) = args.get("config") {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {path}"))?;
        ExperimentConfig::from_json(&text)?
    } else {
        let name = args.get("preset").unwrap_or("quick");
        preset(name).with_context(|| {
            format!("unknown preset {name:?}; see `crossfed list-presets`")
        })?
    };
    if let Some(a) = args.get("agg") {
        cfg.aggregation = crate::aggregation::AggregationKind::parse(a)
            .with_context(|| format!("unknown aggregation {a:?}"))?;
    }
    if let Some(r) = args.get_usize("rounds")? {
        cfg.rounds = r;
    }
    if let Some(p) = args.get("protocol") {
        cfg.protocol = crate::netsim::Protocol::parse(p)
            .with_context(|| format!("unknown protocol {p:?}"))?;
    }
    if let Some(c) = args.get("compression") {
        cfg.compression = crate::compress::Compression::parse(c)
            .with_context(|| format!("unknown compression {c:?}"))?;
    }
    if let Some(l) = args.get("lossless") {
        cfg.lossless = crate::compress::LosslessStage::parse(l)
            .with_context(|| format!("unknown lossless stage {l:?}"))?;
    }
    if let Some(s) = args.get("partition") {
        cfg.partition = crate::partition::PartitionStrategy::parse(s)
            .with_context(|| format!("unknown partition {s:?}"))?;
    }
    if let Some(seed) = args.get_usize("seed")? {
        cfg.seed = seed as u64;
    }
    if args.flag("no-encrypt") {
        cfg.encrypt = false;
    }
    if args.flag("hierarchical") {
        cfg.hierarchical = true;
    }
    if args.flag("par-rounds") {
        cfg.par_rounds = true;
    }
    if args.flag("spot") {
        cfg.spot = true;
    }
    if let Some(n) = args.get_usize("history-every")? {
        cfg.history_every = n;
    }
    if let Some(path) = args.get("history-csv") {
        cfg.history_csv = Some(path.to_string());
    }
    if let Some(p) = args.get("placement") {
        cfg.placement = crate::cost::Placement::parse(p)?;
    }
    if let Some(path) = args.get("price-book") {
        cfg.price_book =
            crate::cost::PriceBook::load(std::path::Path::new(path))?;
    }
    if let Some(f) = args.get("fault") {
        cfg.faults = crate::netsim::FaultPlan::parse(f)
            .with_context(|| format!("--fault {f:?}"))?;
    }
    if let Some(dir) = args.get("wal") {
        cfg.wal_dir = Some(dir.to_string());
    }
    if let Some(budget) = args.get_f64("target-cost")? {
        if !(budget > 0.0) {
            bail!("--target-cost must be a positive dollar amount");
        }
        cfg.target_cost = Some(budget);
    }
    cfg.validate()?;
    Ok(cfg)
}

/// Cluster for a run: the paper's 3 clouds, each scaled to
/// `nodes_per_cloud` AZ-level worker nodes.
fn build_cluster(args: &Args) -> Result<ClusterSpec> {
    let npc = args.get_usize("nodes-per-cloud")?.unwrap_or(1);
    if npc == 0 {
        bail!("--nodes-per-cloud must be >= 1");
    }
    Ok(ClusterSpec::paper_default_scaled(npc))
}

fn artifacts_dir(args: &Args) -> PathBuf {
    PathBuf::from(args.get("artifacts").unwrap_or("artifacts"))
}

/// Run one experiment, backend chosen by --mock.
pub fn run_experiment(
    cfg: &ExperimentConfig,
    cluster: ClusterSpec,
    mock: bool,
    artifacts: &std::path::Path,
    model_preset: &str,
) -> Result<RunResult> {
    run_experiment_ckpt(cfg, cluster, mock, artifacts, model_preset, None, None)
}

/// `run_experiment` with optional restore/save paths.
pub fn run_experiment_ckpt(
    cfg: &ExperimentConfig,
    cluster: ClusterSpec,
    mock: bool,
    artifacts: &std::path::Path,
    model_preset: &str,
    resume: Option<&std::path::Path>,
    save: Option<&std::path::Path>,
) -> Result<RunResult> {
    if mock {
        let backend = MockRuntime::new(0.4);
        let init = ParamSet { leaves: vec![vec![2.0; 64], vec![-1.0; 32]] };
        run_with_backend(cfg, cluster, &backend, init, 4, 16, resume, save)
    } else {
        let manifest = Manifest::load(artifacts, model_preset)?;
        let backend = StepRuntime::load(&manifest)?;
        let init = ParamSet::init(&manifest, cfg.seed);
        let (b, s) = (manifest.model.batch_size, manifest.model.seq_len);
        run_with_backend(cfg, cluster, &backend, init, b, s, resume, save)
    }
}

/// Shared run harness: `--resume DIR` replays the write-ahead log
/// (crash-consistent, bit-identical); `--resume FILE` restores a
/// checkpoint snapshot; otherwise a fresh coordinator (which attaches a
/// WAL itself when `cfg.wal_dir` is set).
#[allow(clippy::too_many_arguments)]
fn run_with_backend<B: ComputeBackend + ?Sized>(
    cfg: &ExperimentConfig,
    cluster: ClusterSpec,
    backend: &B,
    init: ParamSet,
    batch_size: usize,
    seq_len: usize,
    resume: Option<&std::path::Path>,
    save: Option<&std::path::Path>,
) -> Result<RunResult> {
    use crate::checkpoint::Checkpoint;
    let mut coord = match resume {
        Some(dir) if dir.is_dir() => {
            let mut cfg = cfg.clone();
            cfg.wal_dir = Some(dir.to_string_lossy().into_owned());
            let coord = Coordinator::resume(
                cfg, cluster, backend, init, batch_size, seq_len,
            )?;
            log::info!(
                "resumed from WAL {dir:?} at round {}",
                coord.rounds_completed()
            );
            coord
        }
        _ => {
            let mut coord = Coordinator::new(
                cfg.clone(),
                cluster,
                backend,
                init,
                batch_size,
                seq_len,
            )?;
            if let Some(path) = resume {
                coord.restore(&Checkpoint::load(path)?)?;
                log::info!("resumed from checkpoint {path:?}");
            }
            coord
        }
    };
    let r = coord.run()?;
    if let Some(path) = save {
        coord.checkpoint().save(path)?;
        log::info!("checkpoint saved to {path:?}");
    }
    Ok(r)
}

fn print_result(r: &RunResult, curve: bool) {
    println!(
        "run {:<18} rounds={:<4} comm={:<10} time={:<10} cost=${:<9.2} eval_loss={:.3} acc={:.1}% {}",
        r.name,
        r.rounds_run,
        human_bytes(r.wire_bytes),
        human_duration(r.sim_secs),
        r.cost_usd(),
        r.final_eval_loss,
        r.acc_pct(),
        if r.reached_target { "(target reached)" } else { "" },
    );
    if curve {
        println!("{}", r.curve_csv());
    }
}

fn cmd_train(args: &Args) -> Result<i32> {
    let cfg = build_config(args)?;
    let cluster = build_cluster(args)?;
    let model_preset = args.get("model-preset").unwrap_or("tiny");
    let resume = args.get("resume").map(std::path::PathBuf::from);
    let save = args.get("save-checkpoint").map(std::path::PathBuf::from);
    let r = run_experiment_ckpt(
        &cfg,
        cluster,
        args.flag("mock"),
        &artifacts_dir(args),
        model_preset,
        resume.as_deref(),
        save.as_deref(),
    )?;
    print_result(&r, args.flag("curve"));
    Ok(0)
}

/// `serve`: deploy the trained model behind each requested routing
/// policy and compare latency, queues, staleness and dollars.
fn cmd_serve(args: &Args) -> Result<i32> {
    use crate::serve::{RoutePolicy, ServeConfig, ServeResult};
    let name = args.get("preset").unwrap_or("paper-serve");
    let exp = preset(name).with_context(|| {
        format!("unknown preset {name:?}; see `crossfed list-presets`")
    })?;
    let mut base = ServeConfig::from_experiment(&exp);
    if let Some(path) = args.get("from-checkpoint") {
        let ckpt =
            crate::checkpoint::Checkpoint::load(std::path::Path::new(path))?;
        base = base.with_checkpoint(&ckpt);
    }
    if let Some(seed) = args.get_usize("seed")? {
        base.seed = seed as u64;
    }
    if let Some(u) = args.get_usize("users")? {
        base.traffic.users = u as u64;
    }
    if let Some(h) = args.get_f64("hours")? {
        if !(h > 0.0) {
            bail!("--hours must be positive");
        }
        base.duration_secs = h * 3600.0;
    }
    if let Some(s) = args.get_f64("refresh-secs")? {
        base.refresh_period_secs = s;
    }
    if let Some(b) = args.get_usize("max-batch")? {
        base.max_batch = b;
    }
    if let Some(p) = args.get_usize("model-params")? {
        base.service.n_params = p as u64;
        base.model_bytes = crate::transport::dense_param_bytes(p as u64);
    }
    if let Some(path) = args.get("price-book") {
        base.price_book =
            crate::cost::PriceBook::load(std::path::Path::new(path))?;
    }
    let cluster = match args.get_usize("clouds")? {
        None | Some(0) => ClusterSpec::paper_default_scaled(1),
        Some(n) => ClusterSpec::scaled(n, &[1]),
    };
    let routes = args.get("route").unwrap_or("latency,cost,blended:0.5");
    let mut results = Vec::new();
    for r in routes.split(',') {
        let mut cfg = base.clone();
        cfg.route = RoutePolicy::parse(r.trim())?;
        cfg.name = format!("{}-{}", base.name, cfg.route.name());
        let res = crate::serve::run(&cfg, &cluster)?;
        println!(
            "serve {:<26} req={:<9} p50={:.0}ms p99={:.0}ms queue(max)={} \
             stale={:.0}s cost=${:.2} (${:.2}/M-req)",
            res.name,
            res.requests,
            res.p50_ms,
            res.p99_ms,
            res.max_queue_depth,
            res.staleness_mean_secs,
            res.cost_usd(),
            res.usd_per_million(),
        );
        results.push(res);
    }
    let rrefs: Vec<&ServeResult> = results.iter().collect();
    println!("\n{}", report::table_serve(&rrefs));
    let json =
        crate::util::json::Json::arr(results.iter().map(|r| r.to_json()));
    report::save("serve.json", &json.to_string_pretty());
    Ok(0)
}

/// `sweep --placements ... --codecs ...`: one preset over the full
/// placement × codec grid, with a delta table against the first combo.
fn sweep_grid(args: &Args, placements: &str, codecs: &str) -> Result<i32> {
    let name = args.get("preset").unwrap_or("paper-hier-cost");
    let model_preset = args.get("model-preset").unwrap_or("tiny");
    let base = preset(name)
        .with_context(|| format!("unknown preset {name:?}"))?;
    let mut results = Vec::new();
    for p in placements.split(',') {
        for c in codecs.split(',') {
            let mut cfg = base.clone();
            cfg.placement = crate::cost::Placement::parse(p.trim())?;
            cfg.compression = crate::compress::Compression::parse(c.trim())
                .with_context(|| format!("unknown compression {c:?}"))?;
            cfg.name = format!("{}+{}", p.trim(), c.trim());
            if let Some(r) = args.get_usize("rounds")? {
                cfg.rounds = r;
            }
            cfg.validate()?;
            log::info!("sweep grid: running {}", cfg.name);
            let r = run_experiment(
                &cfg,
                build_cluster(args)?,
                args.flag("mock"),
                &artifacts_dir(args),
                model_preset,
            )?;
            print_result(&r, false);
            results.push(r);
        }
    }
    let rrefs: Vec<&RunResult> = results.iter().collect();
    println!("\n{}", report::table_cost(&rrefs));
    let base_cost = results[0].cost_usd().max(1e-9);
    let base_gb = results[0].comm_gb().max(1e-12);
    let base_hours = results[0].sim_hours().max(1e-12);
    let rows: Vec<(&str, Vec<(&str, String)>)> = results
        .iter()
        .map(|r| {
            (
                r.name.as_str(),
                vec![
                    ("cost $", format!("{:.2}", r.cost_usd())),
                    (
                        "Δcost %",
                        format!(
                            "{:+.1}",
                            (r.cost_usd() / base_cost - 1.0) * 100.0
                        ),
                    ),
                    ("comm GB", format!("{:.2}", r.comm_gb())),
                    (
                        "Δcomm %",
                        format!(
                            "{:+.1}",
                            (r.comm_gb() / base_gb - 1.0) * 100.0
                        ),
                    ),
                    (
                        "Δtime %",
                        format!(
                            "{:+.1}",
                            (r.sim_hours() / base_hours - 1.0) * 100.0
                        ),
                    ),
                ],
            )
        })
        .collect();
    println!(
        "{}",
        report::comparison(
            &format!(
                "Placement × codec ablation on {name} (deltas vs {})",
                results[0].name
            ),
            &rows,
        )
    );
    Ok(0)
}

fn cmd_sweep(args: &Args) -> Result<i32> {
    if args.get("placements").is_some() || args.get("codecs").is_some() {
        let placements = args.get("placements").unwrap_or("fixed:0");
        let codecs = args.get("codecs").unwrap_or("none");
        return sweep_grid(args, placements, codecs);
    }
    let list = args
        .get("presets")
        .unwrap_or("paper-fedavg,paper-dynamic,paper-gradient");
    let model_preset = args.get("model-preset").unwrap_or("tiny");
    let mut results = Vec::new();
    let mut configs = Vec::new();
    for name in list.split(',') {
        let cfg = preset(name.trim())
            .with_context(|| format!("unknown preset {name:?}"))?;
        configs.push(cfg.clone());
        log::info!("sweep: running {name}");
        let r = run_experiment(
            &cfg,
            build_cluster(args)?,
            args.flag("mock"),
            &artifacts_dir(args),
            model_preset,
        )?;
        print_result(&r, false);
        results.push(r);
    }
    let refs: Vec<&ExperimentConfig> = configs.iter().collect();
    let rrefs: Vec<&RunResult> = results.iter().collect();
    println!("\n{}", report::table1(&refs));
    println!("{}", report::table2(&rrefs));
    println!("{}", report::table3(&rrefs));
    println!("{}", report::table_cost(&rrefs));
    Ok(0)
}

fn cmd_inspect(args: &Args) -> Result<i32> {
    let name = args.get("preset").unwrap_or("paper-fedavg");
    let cfg = preset(name)
        .with_context(|| format!("unknown preset {name:?}"))?;
    println!("{}", cfg.to_json().to_string_pretty());
    println!("\n{}", report::table1(&[&cfg]));
    Ok(0)
}

fn cmd_partition_plan(args: &Args) -> Result<i32> {
    let strategy = args.get("strategy").unwrap_or("dynamic");
    let strategy = crate::partition::PartitionStrategy::parse(strategy)
        .with_context(|| format!("unknown strategy {strategy:?}"))?;
    let n = args.get_usize("platforms")?.unwrap_or(3);
    if n == 0 {
        bail!("--platforms must be >= 1");
    }
    let cluster = if n == 3 {
        ClusterSpec::paper_default()
    } else {
        ClusterSpec::heterogeneous(n, 3.0)
    };
    let corpus = SyntheticCorpus::generate(&Default::default());
    let caps: Vec<f64> =
        cluster.platforms.iter().map(|p| p.compute_speed).collect();
    let mut planner = PartitionPlanner::new(strategy, 42);
    let plan = planner.plan(&corpus, &cluster, &caps);
    println!(
        "partition plan: strategy={} generation={} encrypted={}",
        plan.strategy.name(),
        plan.generation,
        plan.require_encryption
    );
    for (shard, p) in plan.shards.iter().zip(&cluster.platforms) {
        println!(
            "  {:<8} speed={:<5.2} docs={:<5} tokens={:<8} topics={:?}",
            p.name,
            p.compute_speed,
            shard.doc_ids.len(),
            shard.n_tokens(),
            shard.topic_counts
        );
    }
    println!("  distribution cost: {}", human_bytes(plan.distribution_bytes()));
    Ok(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn s(v: &[&str]) -> Vec<String> {
        v.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn help_and_list() {
        assert_eq!(run_cli(&s(&["help"])).unwrap(), 0);
        assert_eq!(run_cli(&s(&["list-presets"])).unwrap(), 0);
        assert_eq!(run_cli(&s(&["frobnicate"])).unwrap(), 2);
    }

    #[test]
    fn inspect_and_partition_plan() {
        assert_eq!(
            run_cli(&s(&["inspect", "--preset", "paper-gradient"])).unwrap(),
            0
        );
        assert_eq!(
            run_cli(&s(&["partition-plan", "--strategy", "fixed"])).unwrap(),
            0
        );
        assert!(run_cli(&s(&["inspect", "--preset", "zzz"])).is_err());
    }

    #[test]
    fn train_mock_quick() {
        assert_eq!(
            run_cli(&s(&["train", "--preset", "quick", "--rounds", "3", "--mock"]))
                .unwrap(),
            0
        );
    }

    #[test]
    fn train_hierarchical_scaled() {
        assert_eq!(
            run_cli(&s(&[
                "train", "--preset", "quick", "--rounds", "2", "--mock",
                "--hierarchical", "--nodes-per-cloud", "4",
            ]))
            .unwrap(),
            0
        );
        // async + hierarchical selects the buffered hierarchy and runs
        // end-to-end
        assert_eq!(
            run_cli(&s(&[
                "train", "--preset", "quick", "--rounds", "2", "--mock",
                "--agg", "async", "--hierarchical",
                "--nodes-per-cloud", "2",
            ]))
            .unwrap(),
            0
        );
    }

    #[test]
    fn par_rounds_rejects_async_schedules() {
        // --par-rounds parallelizes the synchronous barrier; both async
        // schedules run on the serial event engine and must be rejected
        // at validation with a pointable error, not a mid-run panic
        for extra in [vec![], vec!["--hierarchical"]] {
            let mut argv = vec![
                "train", "--preset", "quick", "--agg", "async",
                "--par-rounds",
            ];
            argv.extend(extra.iter());
            let args = Args::parse(&s(&argv), &FLAGS).unwrap();
            let err = build_config(&args).unwrap_err();
            assert!(
                format!("{err:#}").contains("par_rounds"),
                "{argv:?}: {err:#}"
            );
        }
    }

    #[test]
    fn train_spot_market_preset() {
        // the paper-hier-async-spot preset (buffered hierarchy, spot
        // billing, scripted preemption churn) runs end-to-end; shrink it
        // so the roster plan stays valid (--nodes-per-cloud >= 2)
        assert_eq!(
            run_cli(&s(&[
                "train", "--preset", "paper-hier-async-spot", "--rounds",
                "4", "--mock", "--nodes-per-cloud", "2",
            ]))
            .unwrap(),
            0
        );
        // elastic membership via --fault: a leave + rejoin mid-run
        assert_eq!(
            run_cli(&s(&[
                "train", "--preset", "quick", "--rounds", "4", "--mock",
                "--agg", "async", "--hierarchical",
                "--nodes-per-cloud", "2", "--spot",
                "--fault", "worker-leave:node=1,at=1;worker-join:node=1,at=3",
            ]))
            .unwrap(),
            0
        );
        // leaving a node that was never there is a clean error
        let args = Args::parse(
            &s(&["train", "--preset", "quick", "--fault",
                 "worker-leave:at=1"]),
            &FLAGS,
        )
        .unwrap();
        assert!(build_config(&args).is_err());
    }

    #[test]
    fn train_with_fault_injection() {
        // a mid-run gateway kill + slowdown must still complete training
        assert_eq!(
            run_cli(&s(&[
                "train", "--preset", "quick", "--rounds", "4", "--mock",
                "--hierarchical", "--nodes-per-cloud", "2",
                "--fault",
                "gateway-down:cloud=1,at=1;node-slowdown:node=1,at=2,factor=2",
            ]))
            .unwrap(),
            0
        );
        // bad spec is a clean error
        let args = Args::parse(
            &s(&["train", "--preset", "quick", "--fault", "meteor:at=1"]),
            &FLAGS,
        )
        .unwrap();
        assert!(build_config(&args).is_err());
        // gateway-down without a standby member errors at build, not mid-run
        assert!(run_cli(&s(&[
            "train", "--preset", "quick", "--rounds", "4", "--mock",
            "--hierarchical", "--fault", "gateway-down:cloud=1,at=1",
        ]))
        .is_err());
    }

    #[test]
    fn train_with_placement_and_price_book() {
        // auto placement end-to-end on the mock backend
        assert_eq!(
            run_cli(&s(&[
                "train", "--preset", "quick", "--rounds", "2", "--mock",
                "--hierarchical", "--nodes-per-cloud", "2",
                "--placement", "auto",
            ]))
            .unwrap(),
            0
        );
        // --price-book loads a JSON file into the config
        let path = std::env::temp_dir().join("crossfed-cli-pricebook.json");
        std::fs::write(
            &path,
            r#"{"name": "cli-book",
                "egress": {"inter-region": [{"usd_per_gb": 0.5}]}}"#,
        )
        .unwrap();
        let args = Args::parse(
            &s(&["train", "--preset", "quick", "--price-book",
                 path.to_str().unwrap(), "--placement", "fixed:1"]),
            &FLAGS,
        )
        .unwrap();
        let cfg = build_config(&args).unwrap();
        assert_eq!(cfg.price_book.name, "cli-book");
        assert_eq!(cfg.placement, crate::cost::Placement::Fixed(1));
        std::fs::remove_file(&path).ok();
        // bad placement / missing book are clean errors
        for bad in [
            vec!["train", "--placement", "nowhere"],
            vec!["train", "--price-book", "/nonexistent/book.json"],
        ] {
            let args = Args::parse(&s(&bad), &FLAGS).unwrap();
            assert!(build_config(&args).is_err(), "{bad:?}");
        }
        // fixed:9 on a 3-cloud cluster errors at build, not mid-run
        assert!(run_cli(&s(&[
            "train", "--preset", "quick", "--rounds", "2", "--mock",
            "--placement", "fixed:9",
        ]))
        .is_err());
    }

    #[test]
    fn config_overrides_apply() {
        let args = Args::parse(
            &s(&["train", "--preset", "quick", "--agg", "gradient",
                 "--rounds", "7", "--protocol", "quic",
                 "--compression", "topk:0.1", "--lossless", "auto",
                 "--no-encrypt"]),
            &FLAGS,
        )
        .unwrap();
        let cfg = build_config(&args).unwrap();
        assert_eq!(cfg.aggregation.name(), "gradient");
        assert_eq!(cfg.rounds, 7);
        assert_eq!(cfg.protocol.name(), "quic");
        assert_eq!(cfg.lossless, crate::compress::LosslessStage::Auto);
        assert!(!cfg.encrypt);
        // bad stage is a clean error
        let args = Args::parse(
            &s(&["train", "--preset", "quick", "--lossless", "gzip"]),
            &FLAGS,
        )
        .unwrap();
        assert!(build_config(&args).is_err());
    }

    #[test]
    fn train_with_checkpoint_roundtrip() {
        let base = std::env::temp_dir().join("crossfed-cli-ckpt");
        let b = base.to_str().unwrap();
        assert_eq!(
            run_cli(&s(&["train", "--preset", "quick", "--rounds", "3",
                         "--mock", "--save-checkpoint", b]))
            .unwrap(),
            0
        );
        assert_eq!(
            run_cli(&s(&["train", "--preset", "quick", "--rounds", "2",
                         "--mock", "--resume", b]))
            .unwrap(),
            0
        );
        // wrong-shape resume (real model vs mock ckpt) must error cleanly
        std::fs::remove_file(base.with_extension("json")).ok();
        std::fs::remove_file(base.with_extension("bin")).ok();
    }

    #[test]
    fn train_wal_crash_resume_cli() {
        let dir = std::env::temp_dir().join("crossfed-cli-wal");
        std::fs::remove_dir_all(&dir).ok();
        let d = dir.to_str().unwrap();
        // the injected crash aborts the run with the typed error...
        let err = run_cli(&s(&[
            "train", "--preset", "quick", "--rounds", "4", "--mock",
            "--wal", d, "--fault", "coordinator-crash:at=2",
        ]))
        .unwrap_err();
        assert!(
            err.downcast_ref::<crate::coordinator::CoordinatorCrashed>()
                .is_some(),
            "{err:#}"
        );
        // ...and --resume DIR replays the WAL and finishes the run
        assert_eq!(
            run_cli(&s(&[
                "train", "--preset", "quick", "--rounds", "4", "--mock",
                "--resume", d,
            ]))
            .unwrap(),
            0
        );
        // a crash fault without --wal is rejected at validation
        let args = Args::parse(
            &s(&["train", "--preset", "quick",
                 "--fault", "coordinator-crash:at=2"]),
            &FLAGS,
        )
        .unwrap();
        assert!(build_config(&args).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn train_stops_at_cost_budget() {
        let args = Args::parse(
            &s(&["train", "--preset", "quick", "--rounds", "6", "--mock",
                 "--target-cost", "0.0000001"]),
            &FLAGS,
        )
        .unwrap();
        let cfg = build_config(&args).unwrap();
        assert_eq!(cfg.target_cost, Some(0.0000001));
        let r = run_experiment_ckpt(
            &cfg,
            build_cluster(&args).unwrap(),
            true,
            std::path::Path::new("artifacts"),
            "tiny",
            None,
            None,
        )
        .unwrap();
        assert!(r.rounds_run < 6, "budget should stop the run early");
        // non-positive budgets are a clean error
        let args =
            Args::parse(&s(&["train", "--target-cost", "0"]), &FLAGS).unwrap();
        assert!(build_config(&args).is_err());
    }

    #[test]
    fn train_par_rounds_and_history_knobs() {
        // end-to-end: parallel hierarchical rounds + thinned history +
        // streamed CSV on the mock backend
        let csv = std::env::temp_dir().join("crossfed-cli-history.csv");
        assert_eq!(
            run_cli(&s(&[
                "train", "--preset", "quick", "--rounds", "4", "--mock",
                "--hierarchical", "--nodes-per-cloud", "2", "--par-rounds",
                "--history-every", "2",
                "--history-csv", csv.to_str().unwrap(),
            ]))
            .unwrap(),
            0
        );
        let text = std::fs::read_to_string(&csv).unwrap();
        // header + one row per round, streamed regardless of thinning
        assert_eq!(text.trim().lines().count(), 5, "{text}");
        assert!(text.starts_with("round,"));
        std::fs::remove_file(&csv).ok();
        // --par-rounds without --hierarchical is rejected at validation
        let args = Args::parse(
            &s(&["train", "--preset", "quick", "--par-rounds"]),
            &FLAGS,
        )
        .unwrap();
        assert!(build_config(&args).is_err());
        // --history-every 0 is rejected at validation
        let args = Args::parse(
            &s(&["train", "--preset", "quick", "--history-every", "0"]),
            &FLAGS,
        )
        .unwrap();
        assert!(build_config(&args).is_err());
    }

    #[test]
    fn serve_runs_each_policy() {
        // a small population so the test stays quick: 3 paper clouds,
        // two hours, all three routing policies end-to-end
        assert_eq!(
            run_cli(&s(&[
                "serve", "--users", "20000", "--hours", "2",
                "--refresh-secs", "1800",
            ]))
            .unwrap(),
            0
        );
        // scaled topology + single policy + service-model override
        assert_eq!(
            run_cli(&s(&[
                "serve", "--users", "10000", "--hours", "1", "--clouds",
                "4", "--route", "cost", "--model-params", "100000000",
                "--max-batch", "8", "--seed", "7",
            ]))
            .unwrap(),
            0
        );
        // bad knobs are clean errors
        assert!(run_cli(&s(&["serve", "--route", "teleport"])).is_err());
        assert!(run_cli(&s(&["serve", "--hours", "0"])).is_err());
        assert!(run_cli(&s(&["serve", "--preset", "zzz"])).is_err());
    }

    #[test]
    fn serve_from_checkpoint_closes_the_loop() {
        let base = std::env::temp_dir().join("crossfed-cli-serve-ckpt");
        let b = base.to_str().unwrap();
        assert_eq!(
            run_cli(&s(&["train", "--preset", "quick", "--rounds", "2",
                         "--mock", "--save-checkpoint", b]))
            .unwrap(),
            0
        );
        // the mock checkpoint's 96 params make service times trivial,
        // but the version lineage and refresh payloads come from it
        assert_eq!(
            run_cli(&s(&[
                "serve", "--from-checkpoint", b, "--users", "5000",
                "--hours", "1", "--route", "latency",
            ]))
            .unwrap(),
            0
        );
        std::fs::remove_file(base.with_extension("json")).ok();
        std::fs::remove_file(base.with_extension("bin")).ok();
    }

    #[test]
    fn sweep_grid_prints_delta_table() {
        assert_eq!(
            run_cli(&s(&[
                "sweep", "--preset", "quick", "--mock", "--rounds", "2",
                "--placements", "fixed:0,fixed:1",
                "--codecs", "none,topk:0.5",
            ]))
            .unwrap(),
            0
        );
        // unknown grid axes are clean errors
        assert!(run_cli(&s(&[
            "sweep", "--preset", "quick", "--mock",
            "--placements", "nowhere",
        ]))
        .is_err());
        assert!(run_cli(&s(&[
            "sweep", "--preset", "quick", "--mock", "--codecs", "bogus",
        ]))
        .is_err());
    }

    #[test]
    fn bad_overrides_rejected() {
        for bad in [
            vec!["train", "--agg", "x"],
            vec!["train", "--protocol", "x"],
            vec!["train", "--compression", "x"],
        ] {
            let args = Args::parse(&s(&bad), &FLAGS).unwrap();
            assert!(build_config(&args).is_err(), "{bad:?}");
        }
    }
}

//! Hand-rolled CLI (no `clap` in the offline image).
//!
//! Subcommands:
//!   train          — run one federated experiment
//!   inspect        — print a preset / config and the Table-1 header
//!   partition-plan — show the partition a strategy produces
//!   sweep          — run a preset list and print Tables 2+3
//!   list-presets   — enumerate preset names

mod args;
mod commands;

pub use args::{Args, ArgsError};
pub use commands::run_cli;

//! Tiny argument parser: `--key value`, `--flag`, positionals.

use std::collections::BTreeMap;

#[derive(Debug, thiserror::Error)]
pub enum ArgsError {
    #[error("unknown option --{0}")]
    Unknown(String),
    #[error("option --{0} requires a value")]
    MissingValue(String),
    #[error("invalid value for --{key}: {value:?}")]
    Invalid { key: String, value: String },
}

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse raw args (no argv[0]). `flag_names` lists boolean flags;
    /// everything else starting with `--` takes a value.
    pub fn parse(
        raw: &[String],
        flag_names: &[&str],
    ) -> Result<Args, ArgsError> {
        let mut a = Args::default();
        let mut it = raw.iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(key) = tok.strip_prefix("--") {
                // --key=value form
                if let Some((k, v)) = key.split_once('=') {
                    a.options.insert(k.to_string(), v.to_string());
                    continue;
                }
                if flag_names.contains(&key) {
                    a.flags.push(key.to_string());
                } else {
                    match it.next() {
                        Some(v) if !v.starts_with("--") => {
                            a.options.insert(key.to_string(), v.clone());
                        }
                        _ => return Err(ArgsError::MissingValue(key.into())),
                    }
                }
            } else {
                a.positional.push(tok.clone());
            }
        }
        Ok(a)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }

    pub fn get_usize(&self, key: &str) -> Result<Option<usize>, ArgsError> {
        match self.get(key) {
            None => Ok(None),
            Some(v) => v.parse().map(Some).map_err(|_| ArgsError::Invalid {
                key: key.into(),
                value: v.into(),
            }),
        }
    }

    pub fn get_f64(&self, key: &str) -> Result<Option<f64>, ArgsError> {
        match self.get(key) {
            None => Ok(None),
            Some(v) => v.parse().map(Some).map_err(|_| ArgsError::Invalid {
                key: key.into(),
                value: v.into(),
            }),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn s(v: &[&str]) -> Vec<String> {
        v.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_mixed() {
        let a = Args::parse(
            &s(&["train", "--preset", "quick", "--rounds", "5", "--verbose"]),
            &["verbose"],
        )
        .unwrap();
        assert_eq!(a.positional, vec!["train"]);
        assert_eq!(a.get("preset"), Some("quick"));
        assert_eq!(a.get_usize("rounds").unwrap(), Some(5));
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn equals_form() {
        let a = Args::parse(&s(&["--preset=paper-fedavg"]), &[]).unwrap();
        assert_eq!(a.get("preset"), Some("paper-fedavg"));
    }

    #[test]
    fn missing_value_rejected() {
        assert!(Args::parse(&s(&["--preset"]), &[]).is_err());
        assert!(Args::parse(&s(&["--a", "--b", "x"]), &[]).is_err());
    }

    #[test]
    fn invalid_numbers_rejected() {
        let a = Args::parse(&s(&["--rounds", "five"]), &[]).unwrap();
        assert!(a.get_usize("rounds").is_err());
        let b = Args::parse(&s(&["--lr", "0.5"]), &[]).unwrap();
        assert_eq!(b.get_f64("lr").unwrap(), Some(0.5));
    }
}

//! Partition planning: who gets how much data.

use crate::cluster::ClusterSpec;
use crate::data::{dirichlet_shards, equal_shards, weighted_shards, Shard, SyntheticCorpus};

/// Partitioning strategy (paper Table 1: Fixed vs Dynamic).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum PartitionStrategy {
    /// equal split once, never revisited
    Fixed,
    /// capacity-weighted, re-planned when the monitor fires
    Dynamic,
    /// topic-skewed non-IID split (heterogeneity generator for Table 3)
    DirichletSkew { alpha: f64 },
}

impl PartitionStrategy {
    pub fn name(&self) -> &'static str {
        match self {
            PartitionStrategy::Fixed => "fixed",
            PartitionStrategy::Dynamic => "dynamic",
            PartitionStrategy::DirichletSkew { .. } => "dirichlet",
        }
    }

    pub fn parse(s: &str) -> Option<PartitionStrategy> {
        let s = s.to_ascii_lowercase();
        if s == "fixed" {
            Some(PartitionStrategy::Fixed)
        } else if s == "dynamic" {
            Some(PartitionStrategy::Dynamic)
        } else if let Some(a) = s.strip_prefix("dirichlet:") {
            a.parse().ok().map(|alpha| PartitionStrategy::DirichletSkew { alpha })
        } else {
            None
        }
    }
}

/// The materialized plan: one shard per platform + bookkeeping.
#[derive(Clone, Debug)]
pub struct PartitionPlan {
    pub shards: Vec<Shard>,
    pub strategy: PartitionStrategy,
    /// capacity weights used (empty for Fixed)
    pub weights: Vec<f64>,
    /// plan generation (bumped on each re-partition)
    pub generation: u64,
    /// distribution must be encrypted in flight ("Ensure Data Security")
    pub require_encryption: bool,
}

impl PartitionPlan {
    /// Total tokens across shards.
    pub fn total_tokens(&self) -> usize {
        self.shards.iter().map(|s| s.n_tokens()).sum()
    }

    /// The byte cost of *distributing* this plan (each platform receives
    /// its shard once per generation) — part of Table 2's ledger.
    pub fn distribution_bytes(&self) -> u64 {
        self.shards.iter().map(|s| (s.n_tokens() * 4) as u64).sum()
    }
}

/// Produces and re-produces plans.
#[derive(Clone, Debug)]
pub struct PartitionPlanner {
    strategy: PartitionStrategy,
    seed: u64,
    generation: u64,
}

impl PartitionPlanner {
    pub fn new(strategy: PartitionStrategy, seed: u64) -> PartitionPlanner {
        PartitionPlanner { strategy, seed, generation: 0 }
    }

    pub fn strategy(&self) -> PartitionStrategy {
        self.strategy
    }

    /// Next plan generation this planner will emit.
    pub fn generation(&self) -> u64 {
        self.generation
    }

    /// Rewind/advance the generation counter (WAL resume: re-issuing
    /// `plan()` at a stored generation regenerates that exact plan, since
    /// every strategy is deterministic in (seed, generation, weights)).
    pub fn set_generation(&mut self, generation: u64) {
        self.generation = generation;
    }

    /// Build the initial plan. `capacities` are the platforms' relative
    /// speeds (used by Dynamic; ignored by Fixed).
    pub fn plan(
        &mut self,
        corpus: &SyntheticCorpus,
        cluster: &ClusterSpec,
        capacities: &[f64],
    ) -> PartitionPlan {
        assert_eq!(capacities.len(), cluster.n());
        let n = cluster.n();
        let shards = match self.strategy {
            PartitionStrategy::Fixed => equal_shards(corpus, n),
            PartitionStrategy::Dynamic => weighted_shards(corpus, capacities),
            PartitionStrategy::DirichletSkew { alpha } => {
                dirichlet_shards(corpus, n, alpha, self.seed ^ self.generation)
            }
        };
        let plan = PartitionPlan {
            shards,
            strategy: self.strategy,
            weights: capacities.to_vec(),
            generation: self.generation,
            require_encryption: true,
        };
        self.generation += 1;
        plan
    }

    /// Re-plan with updated capacity estimates (Dynamic only; Fixed
    /// returns None — that is the point of the ablation).
    pub fn replan(
        &mut self,
        corpus: &SyntheticCorpus,
        cluster: &ClusterSpec,
        new_capacities: &[f64],
    ) -> Option<PartitionPlan> {
        match self.strategy {
            PartitionStrategy::Dynamic => {
                Some(self.plan(corpus, cluster, new_capacities))
            }
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::CorpusConfig;

    fn fixture() -> (SyntheticCorpus, ClusterSpec) {
        (
            SyntheticCorpus::generate(&CorpusConfig {
                n_docs: 60,
                doc_sentences: 3,
                n_topics: 3,
                seed: 11,
            }),
            ClusterSpec::heterogeneous(3, 4.0),
        )
    }

    #[test]
    fn fixed_is_equal() {
        let (corpus, cluster) = fixture();
        let mut p = PartitionPlanner::new(PartitionStrategy::Fixed, 1);
        let plan = p.plan(&corpus, &cluster, &[1.0, 1.0, 1.0]);
        let sizes: Vec<usize> =
            plan.shards.iter().map(|s| s.doc_ids.len()).collect();
        assert_eq!(sizes, vec![20, 20, 20]);
        assert!(plan.require_encryption);
    }

    #[test]
    fn dynamic_follows_capacity() {
        let (corpus, cluster) = fixture();
        let mut p = PartitionPlanner::new(PartitionStrategy::Dynamic, 1);
        let plan = p.plan(&corpus, &cluster, &[4.0, 1.0, 1.0]);
        assert_eq!(plan.shards[0].doc_ids.len(), 40);
        assert_eq!(plan.shards[1].doc_ids.len(), 10);
    }

    #[test]
    fn replan_only_for_dynamic() {
        let (corpus, cluster) = fixture();
        let mut fixed = PartitionPlanner::new(PartitionStrategy::Fixed, 1);
        fixed.plan(&corpus, &cluster, &[1.0; 3]);
        assert!(fixed.replan(&corpus, &cluster, &[9.0, 1.0, 1.0]).is_none());

        let mut dynamic = PartitionPlanner::new(PartitionStrategy::Dynamic, 1);
        let p0 = dynamic.plan(&corpus, &cluster, &[1.0; 3]);
        let p1 = dynamic.replan(&corpus, &cluster, &[4.0, 1.0, 1.0]).unwrap();
        assert!(p1.generation > p0.generation);
        assert!(p1.shards[0].doc_ids.len() > p0.shards[0].doc_ids.len());
    }

    #[test]
    fn parse_strategies() {
        assert_eq!(PartitionStrategy::parse("fixed"), Some(PartitionStrategy::Fixed));
        assert_eq!(PartitionStrategy::parse("dynamic"), Some(PartitionStrategy::Dynamic));
        assert_eq!(
            PartitionStrategy::parse("dirichlet:0.3"),
            Some(PartitionStrategy::DirichletSkew { alpha: 0.3 })
        );
        assert_eq!(PartitionStrategy::parse("nope"), None);
    }

    #[test]
    fn distribution_bytes_counts_tokens() {
        let (corpus, cluster) = fixture();
        let mut p = PartitionPlanner::new(PartitionStrategy::Fixed, 1);
        let plan = p.plan(&corpus, &cluster, &[1.0; 3]);
        assert_eq!(plan.distribution_bytes(), plan.total_tokens() as u64 * 4);
    }
}

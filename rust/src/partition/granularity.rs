//! Granularity control ("Adjust Data Granularity").
//!
//! The partition-granularity trade-off the paper describes — larger
//! partitions lower communication frequency but raise per-platform load —
//! maps in federated training to the *local-steps-per-round* knob E:
//! coarse granularity = many local steps between syncs. This controller
//! adapts E to the measured compute/communication ratio: when rounds are
//! communication-dominated it coarsens (bigger E), when compute-dominated
//! and the model is drifting it refines.

/// Adaptive local-steps controller.
#[derive(Clone, Debug)]
pub struct GranularityController {
    pub min_steps: usize,
    pub max_steps: usize,
    steps: usize,
    /// target fraction of round time spent communicating
    pub target_comm_frac: f64,
    /// hysteresis band around the target
    pub band: f64,
}

impl GranularityController {
    pub fn new(initial: usize, min_steps: usize, max_steps: usize) -> Self {
        assert!(min_steps >= 1 && min_steps <= initial && initial <= max_steps);
        GranularityController {
            min_steps,
            max_steps,
            steps: initial,
            target_comm_frac: 0.3,
            band: 0.1,
        }
    }

    /// Current local steps per round.
    pub fn local_steps(&self) -> usize {
        self.steps
    }

    /// Restore the adapted step count (WAL resume).
    pub fn restore_steps(&mut self, steps: usize) {
        assert!(steps >= self.min_steps && steps <= self.max_steps);
        self.steps = steps;
    }

    /// Update from one round's measured compute and communication time.
    /// Returns the (possibly changed) step count.
    pub fn observe(&mut self, compute_secs: f64, comm_secs: f64) -> usize {
        let total = compute_secs + comm_secs;
        if total <= 0.0 {
            return self.steps;
        }
        let comm_frac = comm_secs / total;
        if comm_frac > self.target_comm_frac + self.band {
            // communication-bound: coarsen (more local work per sync)
            self.steps = (self.steps * 2).min(self.max_steps);
        } else if comm_frac < self.target_comm_frac - self.band {
            // compute-bound: refine toward tighter synchronization
            self.steps = (self.steps / 2).max(self.min_steps);
        }
        self.steps
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn comm_bound_coarsens() {
        let mut g = GranularityController::new(4, 1, 64);
        // 80% of the round is communication
        for _ in 0..10 {
            g.observe(0.2, 0.8);
        }
        assert_eq!(g.local_steps(), 64);
    }

    #[test]
    fn compute_bound_refines() {
        let mut g = GranularityController::new(32, 1, 64);
        for _ in 0..10 {
            g.observe(0.95, 0.05);
        }
        assert_eq!(g.local_steps(), 1);
    }

    #[test]
    fn balanced_holds_steady() {
        let mut g = GranularityController::new(8, 1, 64);
        for _ in 0..10 {
            g.observe(0.7, 0.3);
        }
        assert_eq!(g.local_steps(), 8);
    }

    #[test]
    fn bounds_respected() {
        let mut g = GranularityController::new(2, 2, 4);
        for _ in 0..5 {
            g.observe(0.0, 1.0);
        }
        assert_eq!(g.local_steps(), 4);
        for _ in 0..5 {
            g.observe(1.0, 0.0);
        }
        assert_eq!(g.local_steps(), 2);
    }

    #[test]
    #[should_panic]
    fn invalid_bounds_rejected() {
        GranularityController::new(10, 1, 5);
    }
}

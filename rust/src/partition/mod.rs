//! Data partitioning & distribution (§3.1 and the Figure-2 cycle).
//!
//! The paper's cycle has four phases, all implemented here:
//!
//! 1. **Adjust Data Granularity** — [`GranularityController`] trades
//!    communication frequency against per-platform load by tuning how
//!    many local steps a platform runs per round (coarse partitions =
//!    more local work per sync).
//! 2. **Balance Load Across Platforms** — [`PartitionPlanner`] sizes each
//!    platform's shard by measured capacity.
//! 3. **Ensure Data Security** — distribution plans carry an encryption
//!    requirement flag that the transport layer enforces (see
//!    [`crate::crypto`]).
//! 4. **Monitor and Adjust in Real-Time** — [`LoadMonitor`] watches
//!    per-round step times and triggers re-partitioning when the
//!    imbalance coefficient drifts.

mod granularity;
mod monitor;
mod planner;

pub use granularity::GranularityController;
pub use monitor::LoadMonitor;
pub use planner::{PartitionPlan, PartitionPlanner, PartitionStrategy};

//! Real-time load monitoring ("Monitor and Adjust in Real-Time").
//!
//! Watches per-platform round times with an EWMA and signals when the
//! imbalance coefficient (CV of smoothed round times) exceeds a
//! threshold for long enough — the trigger for dynamic re-partitioning.

use crate::util::stats::{imbalance_cv, Ewma};

/// Per-platform EWMA of round times + rebalance trigger logic.
#[derive(Clone, Debug)]
pub struct LoadMonitor {
    ewmas: Vec<Ewma>,
    /// imbalance CV above which the monitor considers the cluster skewed
    pub cv_threshold: f64,
    /// consecutive skewed rounds required to fire
    pub patience: usize,
    skewed_streak: usize,
    /// rounds to stay quiet after firing (let the new plan settle)
    pub cooldown: usize,
    cooldown_left: usize,
    fired_total: u64,
}

impl LoadMonitor {
    pub fn new(n_platforms: usize, cv_threshold: f64, patience: usize) -> LoadMonitor {
        LoadMonitor {
            ewmas: (0..n_platforms).map(|_| Ewma::new(0.3)).collect(),
            cv_threshold,
            patience,
            skewed_streak: 0,
            cooldown: 5,
            cooldown_left: 0,
            fired_total: 0,
        }
    }

    /// Record one round's per-platform durations; returns `true` when a
    /// re-partition should happen now.
    pub fn observe(&mut self, round_times: &[f64]) -> bool {
        assert_eq!(round_times.len(), self.ewmas.len());
        for (e, &t) in self.ewmas.iter_mut().zip(round_times) {
            e.push(t);
        }
        if self.cooldown_left > 0 {
            self.cooldown_left -= 1;
            return false;
        }
        let cv = self.current_cv();
        if cv > self.cv_threshold {
            self.skewed_streak += 1;
        } else {
            self.skewed_streak = 0;
        }
        if self.skewed_streak >= self.patience {
            self.skewed_streak = 0;
            self.cooldown_left = self.cooldown;
            self.fired_total += 1;
            true
        } else {
            false
        }
    }

    /// Current imbalance CV over smoothed times.
    pub fn current_cv(&self) -> f64 {
        let loads: Vec<f64> =
            self.ewmas.iter().filter_map(|e| e.get()).collect();
        if loads.len() < self.ewmas.len() {
            return 0.0;
        }
        imbalance_cv(&loads)
    }

    /// Smoothed per-platform times → capacity estimates (1/time,
    /// normalized to mean 1). Used as the replan weights.
    pub fn capacity_estimates(&self) -> Vec<f64> {
        let times: Vec<f64> = self
            .ewmas
            .iter()
            .map(|e| e.get().unwrap_or(1.0).max(1e-9))
            .collect();
        let caps: Vec<f64> = times.iter().map(|t| 1.0 / t).collect();
        let mean: f64 = caps.iter().sum::<f64>() / caps.len() as f64;
        caps.iter().map(|c| c / mean).collect()
    }

    pub fn times_fired(&self) -> u64 {
        self.fired_total
    }

    /// Snapshot the mutable trigger state for the WAL (thresholds and
    /// patience are configuration, not state).
    pub fn wal_encode(&self, w: &mut crate::wal::ByteWriter) {
        w.put_u64(self.ewmas.len() as u64);
        for e in &self.ewmas {
            w.put_opt_f64(e.get());
        }
        w.put_u64(self.skewed_streak as u64);
        w.put_u64(self.cooldown_left as u64);
        w.put_u64(self.fired_total);
    }

    /// Restore state written by [`LoadMonitor::wal_encode`].
    pub fn wal_decode(
        &mut self,
        r: &mut crate::wal::ByteReader,
    ) -> anyhow::Result<()> {
        let n = r.get_usize()?;
        anyhow::ensure!(n == self.ewmas.len(), "load-monitor width mismatch");
        for e in &mut self.ewmas {
            e.set_value(r.get_opt_f64()?);
        }
        self.skewed_streak = r.get_usize()?;
        self.cooldown_left = r.get_usize()?;
        self.fired_total = r.get_u64()?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn balanced_cluster_never_fires() {
        let mut m = LoadMonitor::new(3, 0.25, 3);
        for _ in 0..50 {
            assert!(!m.observe(&[1.0, 1.02, 0.98]));
        }
        assert_eq!(m.times_fired(), 0);
    }

    #[test]
    fn skew_fires_after_patience() {
        let mut m = LoadMonitor::new(3, 0.25, 3);
        let mut fired_at = None;
        for round in 0..20 {
            if m.observe(&[1.0, 1.0, 3.0]) {
                fired_at = Some(round);
                break;
            }
        }
        // EWMA needs a few rounds to converge + 3 patience
        let at = fired_at.expect("monitor should fire");
        assert!((2..10).contains(&at), "fired at {at}");
    }

    #[test]
    fn cooldown_suppresses_refiring() {
        let mut m = LoadMonitor::new(2, 0.2, 2);
        let mut fires = 0;
        for _ in 0..30 {
            if m.observe(&[1.0, 4.0]) {
                fires += 1;
            }
        }
        // without cooldown this would fire ~15 times
        assert!((2..=6).contains(&fires), "fires={fires}");
    }

    #[test]
    fn capacity_estimates_invert_times() {
        let mut m = LoadMonitor::new(2, 0.9, 100);
        for _ in 0..20 {
            m.observe(&[1.0, 2.0]);
        }
        let caps = m.capacity_estimates();
        // platform 0 is 2x faster
        assert!((caps[0] / caps[1] - 2.0).abs() < 0.05, "caps={caps:?}");
        // normalized to mean 1
        assert!(((caps[0] + caps[1]) / 2.0 - 1.0).abs() < 1e-9);
    }
}

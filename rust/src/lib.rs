//! # crossfed — cross-cloud federated training of large language models
//!
//! A rust + JAX + Pallas reproduction of *"Research on Key Technologies for
//! Cross-Cloud Federated Training of Large Language Models"* (Yang et al.,
//! 2024). The rust layer is the paper's coordination contribution: data
//! partitioning and distribution, cross-cloud communication optimization,
//! the four model-aggregation algorithms (formulas 1–4), and the
//! security/privacy substrates. The compute (a GPT-style LM with Pallas
//! attention kernels) is AOT-compiled from JAX to HLO and executed through
//! PJRT — python never runs on the training path.

pub mod util;
pub mod model;
pub mod runtime;
pub mod cluster;
pub mod netsim;
pub mod cost;
pub mod compress;
pub mod crypto;
pub mod privacy;
pub mod data;
pub mod partition;
pub mod optimizer;
pub mod aggregation;
pub mod transport;
pub mod metrics;
pub mod config;
pub mod worker;
pub mod coordinator;
pub mod report;
pub mod serve;
pub mod cli;
pub mod testkit;
pub mod checkpoint;
pub mod wal;

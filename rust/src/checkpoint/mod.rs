//! Checkpointing: persist / restore the global model and run state.
//!
//! Long cross-cloud runs (the paper's 100-round × hours-per-round regime)
//! need restartability — a leader crash must not lose a day of training.
//! Format: a JSON header (`<name>.json`) describing shape/round/config
//! hash, plus a raw little-endian f32 blob (`<name>.bin`) with the
//! parameter leaves in manifest order.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::model::ParamSet;
use crate::util::bytes::{f32s_to_le, le_to_f32s};
use crate::util::json::Json;

/// Run state stored alongside the parameters.
#[derive(Clone, Debug, PartialEq)]
pub struct Checkpoint {
    pub params: ParamSet,
    pub round: usize,
    pub global_version: u64,
    pub sim_secs: f64,
    pub wire_bytes: u64,
    /// free-form tag (config name) to catch cross-experiment restores
    pub experiment: String,
}

fn paths(base: &Path) -> (PathBuf, PathBuf) {
    (base.with_extension("json"), base.with_extension("bin"))
}

impl Checkpoint {
    /// Write `<base>.json` + `<base>.bin` atomically-ish (tmp + rename).
    ///
    /// The blob is renamed into place *before* the header: the header is
    /// the commit point, so a crash between the two renames can only
    /// leave a blob without a header (invisible to [`Checkpoint::load`],
    /// which starts from the header) — never a header that points at a
    /// missing blob.
    pub fn save(&self, base: &Path) -> Result<()> {
        let (jpath, bpath) = paths(base);
        if let Some(dir) = base.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)
                    .with_context(|| format!("creating checkpoint dir {dir:?}"))?;
            }
        }
        let header = Json::obj(vec![
            ("experiment", Json::str(self.experiment.clone())),
            ("round", Json::num(self.round as f64)),
            ("global_version", Json::num(self.global_version as f64)),
            ("sim_secs", Json::num(self.sim_secs)),
            ("wire_bytes", Json::num(self.wire_bytes as f64)),
            (
                "leaf_sizes",
                Json::arr(
                    self.params
                        .leaves
                        .iter()
                        .map(|l| Json::num(l.len() as f64)),
                ),
            ),
        ]);
        let tmp_j = jpath.with_extension("json.tmp");
        let tmp_b = bpath.with_extension("bin.tmp");
        std::fs::write(&tmp_j, header.to_string_pretty())
            .with_context(|| format!("writing {tmp_j:?}"))?;
        std::fs::write(&tmp_b, f32s_to_le(&self.params.to_flat()))
            .with_context(|| format!("writing {tmp_b:?}"))?;
        std::fs::rename(&tmp_b, &bpath)
            .with_context(|| format!("publishing blob {bpath:?}"))?;
        std::fs::rename(&tmp_j, &jpath)
            .with_context(|| format!("publishing header {jpath:?}"))?;
        Ok(())
    }

    /// Load a checkpoint written by [`Checkpoint::save`].
    pub fn load(base: &Path) -> Result<Checkpoint> {
        let (jpath, bpath) = paths(base);
        let header = Json::parse(
            &std::fs::read_to_string(&jpath)
                .with_context(|| format!("reading {jpath:?}"))?,
        )?;
        let leaf_sizes: Vec<usize> = header
            .req("leaf_sizes")?
            .as_arr()
            .context("leaf_sizes not an array")?
            .iter()
            .map(|v| v.as_usize().context("bad leaf size"))
            .collect::<Result<_>>()?;
        let blob = match std::fs::read(&bpath) {
            Ok(b) => b,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => bail!(
                "checkpoint header {jpath:?} exists but its blob {bpath:?} \
                 is missing (torn save) — no usable checkpoint"
            ),
            Err(e) => {
                return Err(e)
                    .with_context(|| format!("reading {bpath:?}"));
            }
        };
        let flat = le_to_f32s(&blob).context("ragged f32 blob")?;
        let total: usize = leaf_sizes.iter().sum();
        if flat.len() != total {
            bail!(
                "checkpoint blob has {} f32s, header says {total}",
                flat.len()
            );
        }
        let mut leaves = Vec::with_capacity(leaf_sizes.len());
        let mut off = 0;
        for n in leaf_sizes {
            leaves.push(flat[off..off + n].to_vec());
            off += n;
        }
        Ok(Checkpoint {
            params: ParamSet { leaves },
            round: header.req_usize("round")?,
            global_version: header.req_f64("global_version")? as u64,
            sim_secs: header.req_f64("sim_secs")?,
            wire_bytes: header.req_f64("wire_bytes")? as u64,
            experiment: header.req_str("experiment")?.to_string(),
        })
    }

    /// Guard: refuse restoring into a differently-shaped model.
    pub fn check_compatible(&self, like: &ParamSet) -> Result<()> {
        if self.params.n_leaves() != like.n_leaves() {
            bail!(
                "checkpoint has {} leaves, model expects {}",
                self.params.n_leaves(),
                like.n_leaves()
            );
        }
        for (i, (a, b)) in
            self.params.leaves.iter().zip(&like.leaves).enumerate()
        {
            if a.len() != b.len() {
                bail!("leaf {i}: checkpoint {} vs model {}", a.len(), b.len());
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Checkpoint {
        Checkpoint {
            params: ParamSet {
                leaves: vec![vec![1.5, -2.0, 3.25], vec![0.0; 5]],
            },
            round: 17,
            global_version: 42,
            sim_secs: 1234.5,
            wire_bytes: 987654,
            experiment: "paper-gradient".into(),
        }
    }

    fn tmp_base(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("crossfed-ckpt-test-{name}"))
    }

    #[test]
    fn roundtrip() {
        let base = tmp_base("roundtrip");
        let c = sample();
        c.save(&base).unwrap();
        let back = Checkpoint::load(&base).unwrap();
        assert_eq!(back, c);
        std::fs::remove_file(base.with_extension("json")).ok();
        std::fs::remove_file(base.with_extension("bin")).ok();
    }

    #[test]
    fn detects_truncated_blob() {
        let base = tmp_base("trunc");
        sample().save(&base).unwrap();
        let bpath = base.with_extension("bin");
        let blob = std::fs::read(&bpath).unwrap();
        std::fs::write(&bpath, &blob[..blob.len() - 4]).unwrap();
        assert!(Checkpoint::load(&base).is_err());
        std::fs::remove_file(base.with_extension("json")).ok();
        std::fs::remove_file(bpath).ok();
    }

    #[test]
    fn compatibility_guard() {
        let c = sample();
        c.check_compatible(&c.params).unwrap();
        let wrong =
            ParamSet { leaves: vec![vec![0.0; 3], vec![0.0; 6]] };
        assert!(c.check_compatible(&wrong).is_err());
        let fewer = ParamSet { leaves: vec![vec![0.0; 3]] };
        assert!(c.check_compatible(&fewer).is_err());
    }

    #[test]
    fn missing_files_error_cleanly() {
        let base = tmp_base("missing-nonexistent");
        let err = Checkpoint::load(&base).unwrap_err();
        assert!(format!("{err:#}").contains("reading"));
    }

    #[test]
    fn header_without_blob_is_a_clean_torn_save_error() {
        // the exact torn window save() now prevents: a header that points
        // at a blob that never made it
        let base = tmp_base("torn-pair");
        sample().save(&base).unwrap();
        std::fs::remove_file(base.with_extension("bin")).unwrap();
        let err = Checkpoint::load(&base).unwrap_err().to_string();
        assert!(err.contains("torn save"), "{err}");
        std::fs::remove_file(base.with_extension("json")).ok();
    }

    #[test]
    fn save_into_unwritable_dir_is_an_error_not_silent() {
        // create_dir_all failures must surface (they used to be .ok()'d
        // away, turning into a confusing "No such file" on the tmp write)
        let base = std::path::Path::new(
            "/proc/definitely/not/writable/crossfed-ckpt",
        );
        assert!(sample().save(base).is_err());
    }
}

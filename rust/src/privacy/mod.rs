//! Differential privacy substrate (the paper's "differential privacy
//! techniques" for cross-cloud training).
//!
//! Implements DP-FedAvg-style update privatization: per-worker L2
//! clipping followed by Gaussian noise calibrated to (ε, δ), plus a
//! simple privacy accountant (basic and advanced composition).

use crate::model::ParamSet;
use crate::util::rng::Pcg64;

/// DP configuration for worker updates.
#[derive(Clone, Copy, Debug)]
pub struct DpConfig {
    /// L2 clipping bound C on each worker's update
    pub clip_norm: f64,
    /// noise multiplier z: sigma = z * C
    pub noise_multiplier: f64,
    /// target delta for accounting
    pub delta: f64,
}

impl DpConfig {
    pub fn disabled() -> DpConfig {
        DpConfig { clip_norm: 0.0, noise_multiplier: 0.0, delta: 1e-5 }
    }

    pub fn enabled(&self) -> bool {
        self.noise_multiplier > 0.0 && self.clip_norm > 0.0
    }
}

/// Clip `update` to L2 norm <= `clip_norm` (in place). Returns the
/// pre-clip norm.
pub fn clip_update(update: &mut ParamSet, clip_norm: f64) -> f64 {
    let norm = update.l2_norm();
    if norm > clip_norm && norm > 0.0 {
        update.scale((clip_norm / norm) as f32);
    }
    norm
}

/// Add Gaussian noise N(0, sigma^2) to every coordinate.
pub fn add_gaussian_noise(update: &mut ParamSet, sigma: f64, rng: &mut Pcg64) {
    if sigma <= 0.0 {
        return;
    }
    for leaf in &mut update.leaves {
        for x in leaf.iter_mut() {
            *x += rng.normal_ms(0.0, sigma) as f32;
        }
    }
}

/// Privatize one worker update: clip then noise. Returns pre-clip norm.
pub fn privatize(update: &mut ParamSet, cfg: &DpConfig, rng: &mut Pcg64) -> f64 {
    if !cfg.enabled() {
        return update.l2_norm();
    }
    let pre = clip_update(update, cfg.clip_norm);
    add_gaussian_noise(update, cfg.noise_multiplier * cfg.clip_norm, rng);
    pre
}

/// Tracks cumulative privacy loss across rounds.
///
/// Per-round ε for the Gaussian mechanism at noise multiplier z and the
/// configured δ: ε_round = sqrt(2 ln(1.25/δ)) / z  (classic analytic
/// bound, Dwork & Roth Thm 3.22). Composition:
/// * basic: ε_total = T · ε_round
/// * advanced (Dwork et al.): ε_total = ε·sqrt(2T ln(1/δ')) + T·ε·(e^ε − 1)
#[derive(Clone, Debug)]
pub struct PrivacyAccountant {
    cfg: DpConfig,
    rounds: u64,
}

impl PrivacyAccountant {
    pub fn new(cfg: DpConfig) -> PrivacyAccountant {
        PrivacyAccountant { cfg, rounds: 0 }
    }

    pub fn record_round(&mut self) {
        if self.cfg.enabled() {
            self.rounds += 1;
        }
    }

    pub fn rounds(&self) -> u64 {
        self.rounds
    }

    /// Restore the accounted round count (WAL resume).
    pub fn restore_rounds(&mut self, rounds: u64) {
        self.rounds = rounds;
    }

    /// Per-round ε at the configured δ.
    pub fn epsilon_per_round(&self) -> f64 {
        if !self.cfg.enabled() {
            return 0.0;
        }
        (2.0 * (1.25 / self.cfg.delta).ln()).sqrt() / self.cfg.noise_multiplier
    }

    /// Total ε under basic composition.
    pub fn epsilon_basic(&self) -> f64 {
        self.rounds as f64 * self.epsilon_per_round()
    }

    /// Total ε under advanced composition at slack δ' = δ.
    pub fn epsilon_advanced(&self) -> f64 {
        if self.rounds == 0 || !self.cfg.enabled() {
            return 0.0;
        }
        let e = self.epsilon_per_round();
        let t = self.rounds as f64;
        let dp = self.cfg.delta;
        e * (2.0 * t * (1.0 / dp).ln()).sqrt() + t * e * (e.exp() - 1.0)
    }

    /// The better (smaller) of the two bounds.
    pub fn epsilon(&self) -> f64 {
        if self.rounds == 0 {
            return 0.0;
        }
        self.epsilon_basic().min(self.epsilon_advanced())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn params(v: &[f32]) -> ParamSet {
        ParamSet { leaves: vec![v.to_vec()] }
    }

    #[test]
    fn clip_reduces_norm() {
        let mut p = params(&[3.0, 4.0]); // norm 5
        let pre = clip_update(&mut p, 1.0);
        assert!((pre - 5.0).abs() < 1e-6);
        assert!((p.l2_norm() - 1.0).abs() < 1e-6);
        // direction preserved
        assert!((p.leaves[0][0] / p.leaves[0][1] - 0.75).abs() < 1e-6);
    }

    #[test]
    fn clip_noop_when_under_bound() {
        let mut p = params(&[0.3, 0.4]);
        clip_update(&mut p, 1.0);
        assert_eq!(p.leaves[0], vec![0.3, 0.4]);
    }

    #[test]
    fn noise_statistics() {
        let mut rng = Pcg64::new(1, 0);
        let mut p = ParamSet { leaves: vec![vec![0.0; 20_000]] };
        add_gaussian_noise(&mut p, 0.5, &mut rng);
        let xs = &p.leaves[0];
        let mean: f64 = xs.iter().map(|&x| x as f64).sum::<f64>() / 20_000.0;
        let var: f64 =
            xs.iter().map(|&x| (x as f64 - mean).powi(2)).sum::<f64>() / 20_000.0;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 0.25).abs() < 0.02, "var={var}");
    }

    #[test]
    fn privatize_disabled_is_identity() {
        let mut p = params(&[1.0, 2.0, 3.0]);
        let orig = p.clone();
        privatize(&mut p, &DpConfig::disabled(), &mut Pcg64::new(2, 0));
        assert_eq!(p, orig);
    }

    #[test]
    fn privatize_bounds_sensitivity() {
        let cfg = DpConfig { clip_norm: 1.0, noise_multiplier: 1.0, delta: 1e-5 };
        let mut rng = Pcg64::new(3, 0);
        // two adjacent "datasets" — wildly different raw updates
        let mut a = params(&[100.0, 0.0]);
        let mut b = params(&[0.0, -50.0]);
        clip_update(&mut a, cfg.clip_norm);
        clip_update(&mut b, cfg.clip_norm);
        // post-clip sensitivity is at most 2C
        let d = a.sub(&b).l2_norm();
        assert!(d <= 2.0 * cfg.clip_norm + 1e-6);
        privatize(&mut a, &cfg, &mut rng);
        assert!(a.l2_norm() > 0.0);
    }

    #[test]
    fn accountant_grows_and_advanced_wins_for_many_rounds() {
        // advanced composition only beats basic when per-round ε is small,
        // i.e. at high noise multipliers
        let cfg = DpConfig { clip_norm: 1.0, noise_multiplier: 50.0, delta: 1e-5 };
        let mut acc = PrivacyAccountant::new(cfg);
        assert_eq!(acc.epsilon(), 0.0);
        for _ in 0..100 {
            acc.record_round();
        }
        assert_eq!(acc.rounds(), 100);
        let basic = acc.epsilon_basic();
        let adv = acc.epsilon_advanced();
        assert!(basic > 0.0 && adv > 0.0);
        // for small per-round eps and many rounds, advanced < basic
        assert!(adv < basic, "adv={adv} basic={basic}");
        assert_eq!(acc.epsilon(), adv.min(basic));
    }

    #[test]
    fn accountant_ignores_rounds_when_disabled() {
        let mut acc = PrivacyAccountant::new(DpConfig::disabled());
        acc.record_round();
        assert_eq!(acc.rounds(), 0);
        assert_eq!(acc.epsilon(), 0.0);
    }

    #[test]
    fn more_noise_less_epsilon() {
        let e1 = PrivacyAccountant::new(DpConfig {
            clip_norm: 1.0, noise_multiplier: 1.0, delta: 1e-5,
        })
        .epsilon_per_round();
        let e4 = PrivacyAccountant::new(DpConfig {
            clip_norm: 1.0, noise_multiplier: 4.0, delta: 1e-5,
        })
        .epsilon_per_round();
        assert!(e4 < e1 / 3.9);
    }
}

//! Paper-style table formatting + figure-series CSV emission.

use crate::metrics::RunResult;

/// Render Table 1 (experimental setup) for a set of configs.
pub fn table1(configs: &[&crate::config::ExperimentConfig]) -> String {
    let mut out = String::new();
    out.push_str("Table 1: Experimental Setup\n");
    out.push_str(&format!("{:<28} | {}\n", "Parameter", "Value"));
    out.push_str(&format!("{:-<28}-+-{:-<40}\n", "", ""));
    let aggs: Vec<&str> = configs.iter().map(|c| c.aggregation.name()).collect();
    let parts: Vec<String> = {
        let mut v: Vec<String> = configs
            .iter()
            .map(|c| c.partition.name().to_string())
            .collect();
        v.dedup();
        v
    };
    let protos: Vec<&str> = {
        let mut v: Vec<&str> =
            configs.iter().map(|c| c.protocol.name()).collect();
        v.dedup();
        v
    };
    let c0 = configs[0];
    out.push_str(&format!("{:<28} | {}\n", "Number of Cloud Platforms", 3));
    out.push_str(&format!(
        "{:<28} | {}\n",
        "Dataset", "Synthetic topic corpus (WikiText-103 stand-in)"
    ));
    out.push_str(&format!(
        "{:<28} | {}\n",
        "Model Type", "GPT-style LM (JAX+Pallas via PJRT)"
    ));
    out.push_str(&format!(
        "{:<28} | {}\n",
        "Aggregation Algorithms",
        aggs.join(", ")
    ));
    out.push_str(&format!(
        "{:<28} | {}\n",
        "Data Partitioning Strategy",
        parts.join(", ")
    ));
    out.push_str(&format!(
        "{:<28} | {}\n",
        "Communication Protocols",
        protos.join(", ")
    ));
    out.push_str(&format!(
        "{:<28} | {}\n",
        "Number of Training Rounds", c0.rounds
    ));
    out
}

/// Render Table 2: communication overhead + training time per algorithm.
pub fn table2(results: &[&RunResult]) -> String {
    let mut out = String::new();
    out.push_str(
        "Table 2: Communication Overhead and Training Time for Different \
         Aggregation Algorithms\n",
    );
    out.push_str(&format!(
        "{:<22} | {:>26} | {:>21}\n",
        "Aggregation Algorithm", "Communication Overhead (GB)", "Training Time (Hours)"
    ));
    out.push_str(&format!("{:-<22}-+-{:-<27}-+-{:-<21}\n", "", "", ""));
    for r in results {
        out.push_str(&format!(
            "{:<22} | {:>27.2} | {:>21.1}\n",
            r.name,
            r.comm_gb(),
            r.sim_hours()
        ));
    }
    out
}

/// Render Table 3: convergence accuracy + final loss per algorithm.
pub fn table3(results: &[&RunResult]) -> String {
    let mut out = String::new();
    out.push_str(
        "Table 3: Model Convergence Accuracy and Loss for Different \
         Aggregation Algorithms\n",
    );
    out.push_str(&format!(
        "{:<22} | {:>25} | {:>17}\n",
        "Aggregation Algorithm", "Convergence Accuracy (%)", "Final Loss Value"
    ));
    out.push_str(&format!("{:-<22}-+-{:-<25}-+-{:-<17}\n", "", "", ""));
    for r in results {
        out.push_str(&format!(
            "{:<22} | {:>25.1} | {:>17.3}\n",
            r.name,
            r.acc_pct(),
            r.final_eval_loss
        ));
    }
    out
}

/// Render the dollar-cost breakdown table (the paper's "reduced training
/// costs" claim, measured): compute vs egress per link class, per run.
pub fn table_cost(results: &[&RunResult]) -> String {
    use crate::netsim::LinkClass;
    let mut out = String::new();
    out.push_str("Table C: Training Cost Breakdown (USD, cumulative)\n");
    out.push_str(&format!(
        "{:<22} | {:>10} | {:>10} | {:>12} | {:>12} | {:>10}\n",
        "Run", "Compute $", "Intra-AZ $", "Intra-Reg $", "Inter-Reg $", "Total $"
    ));
    out.push_str(&format!(
        "{:-<22}-+-{:-<10}-+-{:-<10}-+-{:-<12}-+-{:-<12}-+-{:-<10}\n",
        "", "", "", "", "", ""
    ));
    for r in results {
        out.push_str(&format!(
            "{:<22} | {:>10.2} | {:>10.4} | {:>12.4} | {:>12.4} | {:>10.2}\n",
            r.name,
            r.cost.compute_total_usd(),
            r.cost.egress_class_usd(LinkClass::IntraAz),
            r.cost.egress_class_usd(LinkClass::IntraRegion),
            r.cost.egress_class_usd(LinkClass::InterRegion),
            r.cost_usd(),
        ));
    }
    out
}

/// Per-cloud cost detail for one run (who pays what).
pub fn table_cost_clouds(r: &RunResult) -> String {
    use crate::netsim::LinkClass;
    let mut out = String::new();
    out.push_str(&format!("Cost by cloud — {}\n", r.name));
    out.push_str(&format!(
        "{:<8} | {:>10} | {:>10} | {:>12} | {:>12} | {:>10}\n",
        "Cloud", "Compute $", "Intra-AZ $", "Intra-Reg $", "Inter-Reg $", "Total $"
    ));
    for c in 0..r.cost.n_clouds() {
        out.push_str(&format!(
            "{:<8} | {:>10.2} | {:>10.4} | {:>12.4} | {:>12.4} | {:>10.2}\n",
            format!("cloud{c}"),
            r.cost.compute_usd[c],
            r.cost.egress_usd[c][LinkClass::IntraAz.index()],
            r.cost.egress_usd[c][LinkClass::IntraRegion.index()],
            r.cost.egress_usd[c][LinkClass::InterRegion.index()],
            r.cost.cloud_usd(c),
        ));
    }
    out
}

/// Render the serving table: latency percentiles, queue depths,
/// staleness and serving economics — one row per routing policy.
pub fn table_serve(results: &[&crate::serve::ServeResult]) -> String {
    let mut out = String::new();
    out.push_str("Table S: Cross-Cloud Serving by Routing Policy\n");
    out.push_str(&format!(
        "{:<26} | {:>8} | {:>8} | {:>8} | {:>9} | {:>8} | {:>9} | {:>10}\n",
        "Run", "Req (M)", "p50 ms", "p99 ms", "Max queue", "Stale s", "Egress $", "$ / M-req"
    ));
    out.push_str(&format!(
        "{:-<26}-+-{:-<8}-+-{:-<8}-+-{:-<8}-+-{:-<9}-+-{:-<8}-+-{:-<9}-+-{:-<10}\n",
        "", "", "", "", "", "", "", ""
    ));
    for r in results {
        out.push_str(&format!(
            "{:<26} | {:>8.3} | {:>8.1} | {:>8.1} | {:>9} | {:>8.1} | {:>9.2} | {:>10.2}\n",
            r.name,
            r.requests as f64 / 1e6,
            r.p50_ms,
            r.p99_ms,
            r.max_queue_depth,
            r.staleness_mean_secs,
            r.cost.egress_total_usd(),
            r.usd_per_million(),
        ));
    }
    out
}

/// Generic comparison table for ablation benches (figures).
pub fn comparison(
    title: &str,
    rows: &[(&str, Vec<(&str, String)>)],
) -> String {
    let mut out = String::new();
    out.push_str(title);
    out.push('\n');
    if rows.is_empty() {
        return out;
    }
    let cols: Vec<&str> = rows[0].1.iter().map(|(k, _)| *k).collect();
    out.push_str(&format!("{:<24}", "variant"));
    for c in &cols {
        out.push_str(&format!(" | {c:>18}"));
    }
    out.push('\n');
    out.push_str(&"-".repeat(24 + cols.len() * 21));
    out.push('\n');
    for (name, kvs) in rows {
        out.push_str(&format!("{name:<24}"));
        for (_, v) in kvs {
            out.push_str(&format!(" | {v:>18}"));
        }
        out.push('\n');
    }
    out
}

/// Write a string to `target/report/<name>` (best-effort, for benches).
pub fn save(name: &str, content: &str) {
    let dir = std::path::Path::new("target/report");
    let _ = std::fs::create_dir_all(dir);
    let _ = std::fs::write(dir.join(name), content);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::preset;
    use crate::metrics::RunResult;

    fn result(name: &str, gb: f64, hours: f64, acc: f64, loss: f32) -> RunResult {
        let mut cost = crate::cost::CostBreakdown::zero(3);
        cost.compute_usd = vec![8.0, 6.0, 4.0];
        cost.egress_usd =
            vec![[0.05, 0.0, 0.9], [0.05, 0.0, 1.2], [0.05, 0.0, 0.75]];
        RunResult {
            name: name.into(),
            history: vec![],
            rounds_run: 100,
            sim_secs: hours * 3600.0,
            wire_bytes: (gb * 1e9) as u64,
            wire_bytes_class: [0, 0, (gb * 1e9) as u64],
            final_train_loss: loss,
            final_eval_loss: loss,
            final_eval_acc: acc,
            reached_target: true,
            host_compute_secs: 0.0,
            cost,
        }
    }

    #[test]
    fn table1_mentions_setup() {
        let a = preset("paper-fedavg").unwrap();
        let b = preset("paper-gradient").unwrap();
        let t = table1(&[&a, &b]);
        assert!(t.contains("Number of Cloud Platforms"));
        assert!(t.contains("fedavg, gradient"));
        assert!(t.contains("100"));
    }

    #[test]
    fn table2_formats_rows() {
        let r1 = result("fedavg", 4.5, 12.0, 0.875, 0.34);
        let r2 = result("gradient", 3.6, 9.8, 0.915, 0.27);
        let t = table2(&[&r1, &r2]);
        assert!(t.contains("4.50"));
        assert!(t.contains("9.8"));
        assert!(t.lines().count() >= 5);
    }

    #[test]
    fn table3_formats_rows() {
        let r = result("dynamic", 3.8, 10.5, 0.902, 0.29);
        let t = table3(&[&r]);
        assert!(t.contains("90.2"));
        assert!(t.contains("0.290"));
    }

    #[test]
    fn table_cost_formats_rows() {
        let r1 = result("star", 4.5, 12.0, 0.875, 0.34);
        let r2 = result("hier", 1.1, 11.8, 0.871, 0.35);
        let t = table_cost(&[&r1, &r2]);
        assert!(t.contains("Training Cost Breakdown"));
        assert!(t.contains("star"));
        assert!(t.contains("hier"));
        // compute total 18.00 and grand total appear
        assert!(t.contains("18.00"), "{t}");
        assert!(t.contains("21.00"), "{t}");
        let per_cloud = table_cost_clouds(&r1);
        assert!(per_cloud.contains("cloud0"));
        assert!(per_cloud.contains("cloud2"));
        assert!(per_cloud.contains("8.00"));
    }

    #[test]
    fn table_serve_formats_rows() {
        let mut cost = crate::cost::CostBreakdown::zero(2);
        cost.compute_usd = vec![40.0, 0.0];
        cost.egress_usd = vec![[0.0, 0.0, 2.0], [0.0, 0.0, 0.0]];
        let r = crate::serve::ServeResult {
            name: "serve-latency".into(),
            policy: "latency".into(),
            requests: 2_000_000,
            sim_secs: 86_400.0,
            events: 4_000_000,
            p50_ms: 180.0,
            p99_ms: 950.0,
            mean_ms: 240.0,
            max_ms: 1800.0,
            mean_queue_depth: 3.5,
            max_queue_depth: 41,
            requests_by_replica: vec![1_500_000, 500_000],
            staleness_mean_secs: 7200.0,
            refreshes: 12,
            wire_bytes: 30_000_000_000,
            wire_bytes_class: [0, 0, 30_000_000_000],
            cost,
        };
        let t = table_serve(&[&r]);
        assert!(t.contains("Routing Policy"));
        assert!(t.contains("serve-latency"));
        // 2M requests, $42 total -> $21.00 per million
        assert!(t.contains("2.000"), "{t}");
        assert!(t.contains("21.00"), "{t}");
        assert!(t.contains("950.0"), "{t}");
    }

    #[test]
    fn comparison_renders_grid() {
        let t = comparison(
            "Figure X",
            &[
                ("grpc", vec![("time", "1.0".into()), ("gb", "2.0".into())]),
                ("quic", vec![("time", "0.7".into()), ("gb", "2.1".into())]),
            ],
        );
        assert!(t.contains("grpc"));
        assert!(t.contains("quic"));
        assert!(t.contains("time"));
    }
}

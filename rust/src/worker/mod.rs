//! Cloud-platform worker: local training + the update pipeline.
//!
//! One `CloudWorker` stands for one cloud platform's training process.
//! It holds the platform's data shard, runs E local SGD steps per round
//! against the shared PJRT backend, and turns the result into the payload
//! the aggregation algorithm expects (parameter delta or accumulated
//! gradient), privatized and shipped through [`crate::transport`].

use anyhow::Result;

use crate::aggregation::UpdateKind;
use crate::cluster::CloudPlatform;
use crate::data::BatchIter;
use crate::model::ParamSet;
use crate::privacy::{privatize, DpConfig};
use crate::runtime::ComputeBackend;
use crate::util::rng::Pcg64;

/// Result of one local-training round on a platform.
#[derive(Clone, Debug)]
pub struct LocalRound {
    /// the outgoing update (delta or gradient-sum per `UpdateKind`)
    pub update: ParamSet,
    /// mean training loss across the local steps (L_i in formula 2)
    pub mean_loss: f32,
    /// simulated compute seconds (platform speed + stragglers applied)
    pub compute_secs: f64,
    /// real host seconds spent in the backend (profiling)
    pub host_secs: f64,
    /// pre-clip update norm (DP diagnostics)
    pub preclip_norm: f64,
}

/// One simulated cloud platform's training state.
pub struct CloudWorker {
    pub id: usize,
    pub platform: CloudPlatform,
    pub n_samples: usize,
    batches: BatchIter,
    straggle_rng: Pcg64,
    dp_rng: Pcg64,
    /// async bookkeeping: global version this worker's params are based on
    pub base_version: u64,
    /// round-persistent scratch for the local parameter copy — avoids
    /// cloning (allocating) the full global model every round
    params_buf: ParamSet,
}

impl CloudWorker {
    pub fn new(
        id: usize,
        platform: CloudPlatform,
        shard_tokens: &[i32],
        batch_size: usize,
        seq_len: usize,
        seed: u64,
    ) -> CloudWorker {
        CloudWorker {
            id,
            platform,
            n_samples: shard_tokens.len(),
            batches: BatchIter::new(shard_tokens, batch_size, seq_len, seed ^ (id as u64) << 17),
            straggle_rng: Pcg64::new(seed, 0x57_0000 + id as u64),
            dp_rng: Pcg64::new(seed, 0xD9_0000 + id as u64),
            base_version: 0,
            params_buf: ParamSet::default(),
        }
    }

    /// Replace this worker's shard (dynamic re-partitioning).
    pub fn set_shard(&mut self, shard_tokens: &[i32], batch_size: usize, seq_len: usize, seed: u64) {
        self.n_samples = shard_tokens.len();
        self.batches = BatchIter::new(
            shard_tokens,
            batch_size,
            seq_len,
            seed ^ (self.id as u64) << 21,
        );
    }

    /// Snapshot this worker's mutable state for the WAL: the straggler,
    /// DP-noise and batch-sampler RNG streams, the async base version and
    /// the (fault-mutable) compute speed. The shard itself is not stored —
    /// it is regenerated bit-identically from the partition plan on
    /// resume, after which these RNG states are overlaid.
    pub fn wal_encode(&self, w: &mut crate::wal::ByteWriter) {
        w.put_u64x4(self.straggle_rng.state_words());
        w.put_u64x4(self.dp_rng.state_words());
        w.put_u64x4(self.batches.rng_state());
        w.put_u64(self.base_version);
        w.put_f64(self.platform.compute_speed);
    }

    /// Restore state written by [`CloudWorker::wal_encode`].
    pub fn wal_decode(
        &mut self,
        r: &mut crate::wal::ByteReader,
    ) -> Result<()> {
        self.straggle_rng = Pcg64::from_state_words(r.get_u64x4()?);
        self.dp_rng = Pcg64::from_state_words(r.get_u64x4()?);
        self.batches.restore_rng(r.get_u64x4()?);
        self.base_version = r.get_u64()?;
        self.platform.compute_speed = r.get_f64()?;
        Ok(())
    }

    /// Run `steps` local SGD steps from `global`, produce the update.
    pub fn local_round<B: ComputeBackend + ?Sized>(
        &mut self,
        backend: &B,
        global: &ParamSet,
        kind: UpdateKind,
        steps: usize,
        lr: f32,
        base_step_secs: f64,
        dp: &DpConfig,
    ) -> Result<LocalRound> {
        assert!(steps >= 1);
        // reuse the round-persistent scratch instead of cloning the global
        // model (parallel copy into the existing allocations); borrowed in
        // place so the warm buffer survives early error returns
        self.params_buf.copy_from(global);
        let params = &mut self.params_buf;
        let mut grad_acc: Option<ParamSet> = None;
        let mut loss_sum = 0.0f64;
        let mut compute_secs = 0.0f64;
        let mut host_secs = 0.0f64;

        for _ in 0..steps {
            let batch = self.batches.next_batch();
            let out = backend.train(params, &batch)?;
            loss_sum += out.loss as f64;
            host_secs += out.exec_secs;
            compute_secs +=
                self.platform.step_time(base_step_secs, &mut self.straggle_rng);
            params.axpy(-lr, &out.grads);
            if kind == UpdateKind::Gradient {
                match &mut grad_acc {
                    None => grad_acc = Some(out.grads),
                    Some(acc) => acc.axpy(1.0, &out.grads),
                }
            }
        }

        let mut update = match kind {
            UpdateKind::ParamDelta => params.sub(global),
            // gradient *sum* over local steps: same step magnitude as the
            // delta path under server lr == local lr, so the algorithms
            // are comparable at equal round counts (formula 3 with the
            // sum absorbed into η)
            UpdateKind::Gradient => grad_acc.expect("steps >= 1"),
        };
        let preclip_norm = privatize(&mut update, dp, &mut self.dp_rng);

        Ok(LocalRound {
            update,
            mean_loss: (loss_sum / steps as f64) as f32,
            compute_secs,
            host_secs,
            preclip_norm,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::MockRuntime;

    fn worker(id: usize) -> CloudWorker {
        let tokens: Vec<i32> = (0..400).map(|i| i % 96).collect();
        CloudWorker::new(id, CloudPlatform::new("t", 1.0), &tokens, 4, 16, 9)
    }

    fn global() -> ParamSet {
        ParamSet { leaves: vec![vec![1.0; 32]] }
    }

    #[test]
    fn param_delta_moves_toward_local_optimum() {
        let backend = MockRuntime::new(0.5);
        let mut w = worker(0);
        let g = global();
        let r = w
            .local_round(&backend, &g, UpdateKind::ParamDelta, 5, 5.0, 1.0,
                         &DpConfig::disabled())
            .unwrap();
        assert!(r.update.l2_norm() > 0.0);
        assert!(r.mean_loss > 0.0);
        assert!((r.compute_secs - 5.0).abs() < 1e-9);
        // applying the delta must reduce local loss
        let mut moved = g.clone();
        moved.axpy(1.0, &r.update);
        let b = w.batches.next_batch();
        let before = backend.train(&g, &b).unwrap().loss;
        let after = backend.train(&moved, &b).unwrap().loss;
        assert!(after < before);
    }

    #[test]
    fn gradient_sum_matches_delta_for_sgd() {
        // with plain local SGD: delta == -lr * grad_sum exactly
        let backend = MockRuntime::new(0.3);
        let g = global();
        let lr = 2.0;

        let mut w1 = worker(1);
        let d = w1
            .local_round(&backend, &g, UpdateKind::ParamDelta, 3, lr, 1.0,
                         &DpConfig::disabled())
            .unwrap();
        let mut w2 = worker(1); // identical stream
        let gr = w2
            .local_round(&backend, &g, UpdateKind::Gradient, 3, lr, 1.0,
                         &DpConfig::disabled())
            .unwrap();
        let mut reconstructed = gr.update.clone();
        reconstructed.scale(-lr);
        let diff = reconstructed.sub(&d.update).l2_norm();
        assert!(diff < 1e-4, "diff={diff}");
    }

    #[test]
    fn slow_platform_takes_longer() {
        let backend = MockRuntime::new(0.1);
        let tokens: Vec<i32> = (0..200).collect();
        let mut slow = CloudWorker::new(
            0,
            CloudPlatform::new("slow", 0.5),
            &tokens,
            2,
            8,
            1,
        );
        let r = slow
            .local_round(&backend, &global(), UpdateKind::ParamDelta, 2, 0.1,
                         1.0, &DpConfig::disabled())
            .unwrap();
        assert!((r.compute_secs - 4.0).abs() < 1e-9); // 2 steps / 0.5 speed
    }

    #[test]
    fn dp_clips_update() {
        let backend = MockRuntime::new(0.5);
        let mut w = worker(2);
        let dp = DpConfig { clip_norm: 0.01, noise_multiplier: 0.0, delta: 1e-5 };
        // noise_multiplier 0 -> dp disabled per DpConfig::enabled; use tiny noise
        let dp = DpConfig { noise_multiplier: 1e-6, ..dp };
        let r = w
            .local_round(&backend, &global(), UpdateKind::ParamDelta, 4, 5.0,
                         1.0, &dp)
            .unwrap();
        assert!(r.preclip_norm > 0.01);
        assert!(r.update.l2_norm() < 0.02);
    }

    #[test]
    fn set_shard_changes_data() {
        let mut w = worker(3);
        let before = w.n_samples;
        w.set_shard(&(0..1000).map(|i| i % 96).collect::<Vec<_>>(), 4, 16, 5);
        assert_ne!(w.n_samples, before);
        assert_eq!(w.n_samples, 1000);
    }
}
